//! Request/response message types and their binary codec.
//!
//! The request set mirrors the SmartRedis client API surface the paper's
//! workflows use: tensor send/retrieve (`put_tensor`/`unpack_tensor`),
//! metadata, model upload, and the RedisAI-style three-step inference
//! (`put_tensor` → `run_model` → `unpack_tensor`).
//!
//! Three composite commands turn N round trips into one:
//!
//! * [`Request::Batch`] carries a pipeline of commands executed in order;
//!   the reply is a [`Response::Batch`] with one entry per command, so an
//!   error mid-batch is reported per entry, never by aborting the rest.
//! * [`Request::MGetTensors`] is the batched-gather fast path (the
//!   dataloader's per-epoch fetch of its 6 snapshots).
//! * [`Request::PollKeys`] waits *server-side* (with capped exponential
//!   backoff) until every named key exists, replacing the client's
//!   busy-poll of `Exists` requests.
//!
//! Batches nest exactly one level: a `Batch` inside a `Batch` is a protocol
//! error, enforced at decode time.
//!
//! Tensor payloads are zero-copy in both directions:
//!
//! * decoding with [`Request::decode_shared`]/[`Response::decode_shared`]
//!   yields tensors whose [`Bytes`] payload is a *view into the frame body*
//!   (a refcount bump), not an owned copy;
//! * encoding a tensor-carrying message can emit just the small header via
//!   [`encode_put_tensor_header_into`]/[`encode_tensor_response_header_into`]
//!   and hand the borrowed payload slice straight to
//!   [`crate::proto::frame::end_split_frame`].

use crate::db::cluster::{SlotAssign, SlotEpoch, N_SLOTS};
use crate::error::{Error, Result};
use crate::tensor::{Bytes, DType, Tensor};

/// Placement of a model execution inside the database (RedisAI semantics:
/// the client names the device; the DB owns the device pool).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Device {
    Cpu,
    /// Logical GPU ordinal on the node (Polaris: 0..=3).
    Gpu(u8),
}

/// Hard cap on the number of entries in one batch / multi-key command.
pub const MAX_BATCH: usize = 4096;

/// One model's registry row reported by `ListModels`: the key, which
/// version is live, how many immutable versions are retained, how many
/// times the live pointer was swapped, and lifetime executions across all
/// versions.
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct ModelEntry {
    pub key: String,
    pub live_version: u64,
    pub n_versions: u64,
    pub swaps: u64,
    pub executions: u64,
}

/// One device's execution statistics reported by `ModelStats` (the
/// registry's per-device accumulators: executions, eval wall-time and
/// slot queue-wait distributions).
#[derive(Debug, Clone, PartialEq)]
pub struct ModelDeviceStat {
    pub device: Device,
    pub executions: u64,
    pub eval_count: u64,
    pub eval_mean_s: f64,
    pub eval_std_s: f64,
    pub queue_count: u64,
    pub queue_mean_s: f64,
    pub queue_std_s: f64,
}

/// Per-field memory-pressure snapshot reported inside [`DbInfo`] while a
/// retention policy is active: how much of the byte budget each field
/// holds, how many generations are resident, and how hard eviction has
/// been working on it.
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct FieldPressure {
    pub field: String,
    /// Tensor payload bytes this field currently holds resident.
    pub resident_bytes: u64,
    /// Resident step generations of the field.
    pub generations: u64,
    /// Keys of this field removed by retention (window, cap, or TTL).
    pub evicted_keys: u64,
    /// Payload bytes of this field freed by retention.
    pub evicted_bytes: u64,
    /// Keys of this field persisted to the spill-to-disk cold tier
    /// (non-zero only with a spill directory configured; untracked keys
    /// spill under the `__untracked` pseudo-field).
    pub spilled_keys: u64,
    /// Payload bytes of this field appended to the cold tier.
    pub spilled_bytes: u64,
}

/// Database statistics reported by `INFO` (and aggregated across shards by
/// the cluster client).
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct DbInfo {
    pub keys: u64,
    pub bytes: u64,
    pub ops: u64,
    pub models: u64,
    /// Lifetime high-water mark of resident tensor bytes.
    pub high_water_bytes: u64,
    /// Tensor keys removed by the retention policy (window retirement,
    /// byte-cap eviction, TTL expiry).
    pub evicted_keys: u64,
    /// Payload bytes freed by eviction.
    pub evicted_bytes: u64,
    /// Writes rejected with backpressure (`busy`) under the byte cap.
    pub busy_rejections: u64,
    /// Subset of `evicted_keys` retired by the wall-clock TTL tier.
    pub ttl_expired_keys: u64,
    /// Active retention policy (0 = the respective limit is off).  On a
    /// cluster aggregate, `retention_max_bytes` is summed across shards
    /// (the cluster-wide budget) while window/TTL are the broadcast value.
    pub retention_window: u64,
    pub retention_max_bytes: u64,
    pub retention_ttl_ms: u64,
    /// Cold-tier counters (all zero while no spill directory is
    /// configured; summed across shards on a cluster aggregate): records
    /// appended to the segment log, their payload bytes, segment files on
    /// disk, `ColdGet` reads served, and victims that never became durable
    /// (append I/O failures + backlog shedding) — non-zero `lost` means
    /// the archive has gaps and the disk deserves attention.
    pub spilled_keys: u64,
    pub spilled_bytes: u64,
    pub spill_segments: u64,
    pub cold_hits: u64,
    pub spill_lost_keys: u64,
    /// Replication/failover counters.  A single server always reports
    /// zero for these — they describe *client-side* cluster behavior and
    /// are filled in by `ClusterClient::info` aggregation: extra replica
    /// copies written beyond the primary, reads answered by a fallback
    /// replica (primary dead or missing the key), successful shard
    /// reconnects after a circuit-breaker trip, and aggregate/broadcast
    /// ops that completed with at least one shard unreachable.
    pub replicated_writes: u64,
    pub read_failovers: u64,
    pub shard_reconnects: u64,
    pub degraded_ops: u64,
    /// Serving counters (zero when the model runtime is disabled): live-
    /// pointer swaps in the model registry (a republish of an existing
    /// key), micro-batched executions that coalesced more than one
    /// request, and the total requests served inside those batches.
    pub model_swaps: u64,
    pub batches: u64,
    pub batched_requests: u64,
    pub engine: String,
    /// Per-field pressure while governance is active (empty otherwise;
    /// merged by field name on a cluster aggregate).
    pub fields: Vec<FieldPressure>,
}

/// Client-to-database commands.
#[derive(Debug, Clone, PartialEq)]
pub enum Request {
    PutTensor { key: String, tensor: Tensor },
    GetTensor { key: String },
    DelTensor { key: String },
    Exists { key: String },
    PutMeta { key: String, value: String },
    GetMeta { key: String },
    ListKeys { prefix: String },
    /// Upload a model artifact (HLO text or `situ-native` text) into the
    /// model registry as a new immutable version of `key`.  Replies
    /// [`Response::Version`] with the version number assigned, and
    /// atomically swaps the key's live pointer to it.
    PutModel { key: String, hlo_text: String },
    /// RedisAI-style in-database inference over stored tensors.
    /// `version` pins one immutable registry version; 0 means "whatever is
    /// live when the call is admitted" (in-flight calls keep their version
    /// across a concurrent hot-swap).
    RunModel {
        key: String,
        version: u64,
        in_keys: Vec<String>,
        out_keys: Vec<String>,
        device: Device,
    },
    Info,
    FlushAll,
    /// A pipeline of commands answered by one [`Response::Batch`] frame.
    /// May not contain another `Batch`.
    Batch(Vec<Request>),
    /// Batched gather: one [`Response::Batch`] of `Tensor`/`NotFound`
    /// entries, one per key, in request order.
    MGetTensors { keys: Vec<String> },
    /// Block server-side until every key exists (capped exponential backoff
    /// between probes), up to `timeout_ms`.  Replies `Bool(true)` once all
    /// keys are present, `Bool(false)` on timeout.  `initial_us`/`cap_us`
    /// bound the server's probe interval.
    PollKeys { keys: Vec<String>, timeout_ms: u64, initial_us: u64, cap_us: u64 },
    /// Delete many tensor keys in one round trip.  Replies with a
    /// [`Response::Batch`] of `Ok`/`NotFound`, one per key in request
    /// order.
    DelKeys { keys: Vec<String> },
    /// Configure the store's retention policy: keep the newest `window`
    /// step generations per field, at most `max_bytes` of tensor payload,
    /// and retire data whose producer has stalled for `ttl_ms` wall-clock
    /// milliseconds (0 disables any limit).  Replies `Ok`.
    Retention { window: u64, max_bytes: u64, ttl_ms: u64 },
    /// List keys resident in the spill-to-disk cold tier with the given
    /// prefix.  Replies `Keys` (empty when no spill directory is
    /// configured).
    ColdList { prefix: String },
    /// Read a retired key back from the cold tier.  Replies `Tensor`, or
    /// `NotFound` when the key was never spilled (or its segment was
    /// dropped by the cold byte cap).  Strictly the cold tier — resident
    /// keys are served by `GetTensor`.
    ColdGet { key: String },
    /// List the model registry: every key with its live version, retained
    /// version count, swap count, and executions.  Replies
    /// [`Response::Models`].
    ListModels,
    /// Per-device execution statistics of the model runtime (the registry's
    /// `DeviceStats` accumulators).  Replies [`Response::ModelStats`].
    ModelStats,
    /// Epoch-versioned slot-ownership exchange.  With `install` empty this
    /// is a fetch: the server replies [`Response::EpochTable`] with
    /// whatever table (possibly none — `shard == u16::MAX`, epoch 0) it
    /// currently holds.  With `install = Some((shard, replicas, table))`
    /// the server adopts `table`, its own shard index `shard`, and the
    /// cluster's replication factor `replicas` (so it accepts writes for
    /// slots it holds as a ring successor, not only as primary) *if* the
    /// table's epoch is not older than the installed one, then replies its
    /// (possibly unchanged) `EpochTable` — so install doubles as fetch and
    /// a concurrent stale installer learns the newer epoch from the reply.
    ClusterEpoch { install: Option<(u16, u16, SlotEpoch)> },
    /// List every resident tensor key whose hash slot falls in
    /// `[lo, hi]` — the reshard driver's per-range export manifest.
    /// Replies [`Response::Keys`], generation-ordered per field so a
    /// transfer window moves whole generations together.
    ExportSlots { lo: u16, hi: u16 },
    /// Append a tensor directly to this server's cold tier (bypassing the
    /// resident store): the cluster-wide retirement path lands every
    /// member of a retired generation in exactly one shard's spill log.
    /// Replies `Ok`, or an error when no spill directory is configured.
    ColdPut { key: String, tensor: Tensor },
}

/// Database-to-client replies.
#[derive(Debug, Clone, PartialEq)]
pub enum Response {
    Ok,
    Tensor(Tensor),
    NotFound,
    Bool(bool),
    Meta(String),
    Keys(Vec<String>),
    Error(String),
    Info(DbInfo),
    /// Per-entry results of a `Batch` or `MGetTensors` request, in request
    /// order.  May not contain another `Batch`.
    Batch(Vec<Response>),
    /// The model registry listing (reply to `ListModels`), sorted by key.
    Models(Vec<ModelEntry>),
    /// Per-device runtime statistics (reply to `ModelStats`), CPU first
    /// then GPU ordinals in order.
    ModelStats(Vec<ModelDeviceStat>),
    /// Version number assigned by a `PutModel` publish.
    Version(u64),
    /// Reply to `ClusterEpoch`: the server's shard index within the
    /// installed table (`u16::MAX` when no table was ever installed — a
    /// standalone server) and the table itself (epoch 0 with no
    /// assignments when unset).
    EpochTable { shard: u16, table: SlotEpoch },
}

// --- encoding helpers -------------------------------------------------------

fn put_str(buf: &mut Vec<u8>, s: &str) {
    buf.extend_from_slice(&(s.len() as u32).to_le_bytes());
    buf.extend_from_slice(s.as_bytes());
}

/// Count-prefixed string list (decoded by `Cur::str_list`).
fn put_str_list(buf: &mut Vec<u8>, items: &[String]) {
    buf.extend_from_slice(&(items.len() as u32).to_le_bytes());
    for s in items {
        put_str(buf, s);
    }
}

/// Wire size of a count-prefixed string list.
fn str_list_wire_size(items: &[String]) -> usize {
    4 + items.iter().map(|s| str_wire_size(s)).sum::<usize>()
}

/// Device placement as one wire byte (0xff = CPU, else the GPU ordinal).
fn put_device(buf: &mut Vec<u8>, d: Device) {
    match d {
        Device::Cpu => buf.push(0xff),
        Device::Gpu(i) => buf.push(i),
    }
}

fn device_from_byte(b: u8) -> Result<Device> {
    match b {
        0xff => Ok(Device::Cpu),
        i if i < 16 => Ok(Device::Gpu(i)),
        i => Err(Error::Protocol(format!("bad device {i}"))),
    }
}

/// Everything of a wire tensor except the payload bytes.
fn put_tensor_header(buf: &mut Vec<u8>, t: &Tensor) {
    buf.push(t.dtype.tag());
    buf.push(t.shape.len() as u8);
    for d in &t.shape {
        buf.extend_from_slice(&(*d as u32).to_le_bytes());
    }
    buf.extend_from_slice(&(t.data.len() as u64).to_le_bytes());
}

fn put_tensor(buf: &mut Vec<u8>, t: &Tensor) {
    put_tensor_header(buf, t);
    buf.extend_from_slice(&t.data);
}

/// Wire size of a length-prefixed string field.
fn str_wire_size(s: &str) -> usize {
    4 + s.len()
}

/// Wire size of a tensor field: dtype tag, ndim, dims, u64 payload length,
/// payload bytes.
fn tensor_wire_size(t: &Tensor) -> usize {
    1 + 1 + 4 * t.shape.len() + 8 + t.data.len()
}

/// Slot-ownership table: epoch u64, count u32, then per assignment
/// `lo, hi, shard, from` as u32 (`from == u32::MAX` means none).
fn put_slot_epoch(buf: &mut Vec<u8>, t: &SlotEpoch) {
    buf.extend_from_slice(&t.epoch.to_le_bytes());
    buf.extend_from_slice(&(t.assignments.len() as u32).to_le_bytes());
    for a in &t.assignments {
        buf.extend_from_slice(&(a.lo as u32).to_le_bytes());
        buf.extend_from_slice(&(a.hi as u32).to_le_bytes());
        buf.extend_from_slice(&(a.shard as u32).to_le_bytes());
        buf.extend_from_slice(&a.from.map(|s| s as u32).unwrap_or(u32::MAX).to_le_bytes());
    }
}

fn slot_epoch_wire_size(t: &SlotEpoch) -> usize {
    8 + 4 + 16 * t.assignments.len()
}

/// Byte-cursor used for decoding.  When constructed over a shared frame
/// body ([`Cur::shared`]), tensor payloads decode as zero-copy views into
/// that body instead of owned copies.
struct Cur<'a> {
    b: &'a [u8],
    i: usize,
    backing: Option<&'a Bytes>,
}

impl<'a> Cur<'a> {
    fn new(b: &'a [u8]) -> Self {
        Cur { b, i: 0, backing: None }
    }

    fn shared(body: &'a Bytes) -> Self {
        Cur { b: body.as_slice(), i: 0, backing: Some(body) }
    }

    fn u8(&mut self) -> Result<u8> {
        let v = *self
            .b
            .get(self.i)
            .ok_or_else(|| Error::Protocol("truncated message".into()))?;
        self.i += 1;
        Ok(v)
    }

    fn u32(&mut self) -> Result<u32> {
        let s = self
            .b
            .get(self.i..self.i + 4)
            .ok_or_else(|| Error::Protocol("truncated u32".into()))?;
        self.i += 4;
        Ok(u32::from_le_bytes([s[0], s[1], s[2], s[3]]))
    }

    fn u64(&mut self) -> Result<u64> {
        let s = self
            .b
            .get(self.i..self.i + 8)
            .ok_or_else(|| Error::Protocol("truncated u64".into()))?;
        self.i += 8;
        Ok(u64::from_le_bytes([s[0], s[1], s[2], s[3], s[4], s[5], s[6], s[7]]))
    }

    /// f64 carried as its IEEE-754 bit pattern in a little-endian u64.
    fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_bits(self.u64()?))
    }

    fn bytes(&mut self, n: usize) -> Result<&'a [u8]> {
        let s = self
            .b
            .get(self.i..self.i + n)
            .ok_or_else(|| Error::Protocol("truncated payload".into()))?;
        self.i += n;
        Ok(s)
    }

    fn str(&mut self) -> Result<String> {
        let n = self.u32()? as usize;
        if n > crate::proto::MAX_FRAME {
            return Err(Error::Protocol("string too large".into()));
        }
        let s = self.bytes(n)?;
        String::from_utf8(s.to_vec()).map_err(|_| Error::Protocol("bad utf8".into()))
    }

    /// Count-prefixed string list, capped at [`MAX_BATCH`] entries.
    fn str_list(&mut self) -> Result<Vec<String>> {
        let n = self.u32()? as usize;
        if n > MAX_BATCH {
            return Err(Error::Protocol(format!("key list of {n} exceeds {MAX_BATCH}")));
        }
        let mut ks = Vec::with_capacity(n);
        for _ in 0..n {
            ks.push(self.str()?);
        }
        Ok(ks)
    }

    fn tensor(&mut self) -> Result<Tensor> {
        let dtype = DType::from_tag(self.u8()?)?;
        let ndim = self.u8()? as usize;
        if ndim > 16 {
            return Err(Error::Protocol(format!("ndim {ndim} too large")));
        }
        let mut shape = Vec::with_capacity(ndim);
        for _ in 0..ndim {
            shape.push(self.u32()? as usize);
        }
        let len = self.u64()? as usize;
        if len > crate::proto::MAX_FRAME {
            return Err(Error::Protocol("tensor payload too large".into()));
        }
        let start = self.i;
        let raw = self.bytes(len)?;
        // Zero-copy when the frame body is shared: the payload is a view
        // into it, kept alive by refcount for as long as the tensor lives.
        let data = match self.backing {
            Some(body) => body.slice(start..start + len),
            None => Bytes::copy_from_slice(raw),
        };
        let t = Tensor { dtype, shape, data };
        t.validate()?;
        Ok(t)
    }

    /// Slot-ownership table (see [`put_slot_epoch`]).  Structurally
    /// validated on decode — a malformed table is a protocol error, never
    /// installed routing state.  Empty assignments are the "no table
    /// installed" sentinel and skip range validation.
    fn slot_epoch(&mut self) -> Result<SlotEpoch> {
        let epoch = self.u64()?;
        let n = self.u32()? as usize;
        if n > N_SLOTS as usize {
            return Err(Error::Protocol(format!("slot table of {n} ranges exceeds {N_SLOTS}")));
        }
        let mut assignments = Vec::with_capacity(n);
        for _ in 0..n {
            let lo = self.u32()?;
            let hi = self.u32()?;
            let shard = self.u32()?;
            let from = self.u32()?;
            if lo >= N_SLOTS as u32 || hi >= N_SLOTS as u32 || shard > u16::MAX as u32 {
                return Err(Error::Protocol("slot assignment out of range".into()));
            }
            assignments.push(SlotAssign {
                lo: lo as u16,
                hi: hi as u16,
                shard: shard as u16,
                from: (from != u32::MAX)
                    .then(|| u16::try_from(from).map_err(|_| Error::Protocol("bad from shard".into())))
                    .transpose()?,
            });
        }
        let table = SlotEpoch { epoch, assignments };
        if !table.assignments.is_empty() {
            table.validate().map_err(Error::Protocol)?;
        }
        Ok(table)
    }

    fn done(&self) -> Result<()> {
        if self.i == self.b.len() {
            Ok(())
        } else {
            Err(Error::Protocol(format!(
                "{} trailing bytes after message",
                self.b.len() - self.i
            )))
        }
    }
}

/// Encode everything of a `PutTensor` request except the payload bytes —
/// the caller pairs this header with the borrowed payload slice via
/// [`crate::proto::frame::end_split_frame`], so the client's hottest path
/// never copies the payload at all.
pub fn encode_put_tensor_header_into(buf: &mut Vec<u8>, key: &str, t: &Tensor) {
    buf.push(req_op::PUT_TENSOR);
    put_str(buf, key);
    put_tensor_header(buf, t);
}

/// Encode everything of a tensor response except the payload bytes (the
/// server's `get_tensor` reply path; pairs with `end_split_frame`).
pub fn encode_tensor_response_header_into(buf: &mut Vec<u8>, t: &Tensor) {
    buf.push(resp_op::TENSOR);
    put_tensor_header(buf, t);
}

/// Contiguous encoding of a `PutTensor` request from a borrowed tensor —
/// byte-identical to `Request::PutTensor { .. }.encode(..)` but without
/// materializing an owned `Request`.  Prefer the split-frame path
/// ([`encode_put_tensor_header_into`]) on hot paths; this remains for
/// callers that need the full body in one buffer.
pub fn encode_put_tensor_into(buf: &mut Vec<u8>, key: &str, t: &Tensor) {
    encode_put_tensor_header_into(buf, key, t);
    buf.extend_from_slice(&t.data);
}

/// Opcode + entry count of a `Batch` request — the client's pipelined send
/// path streams this header, then each entry (tensor payloads as borrowed
/// slices) through a [`crate::proto::frame::FrameSink`].
pub fn encode_batch_request_header_into(buf: &mut Vec<u8>, n: usize) {
    buf.push(req_op::BATCH);
    buf.extend_from_slice(&(n as u32).to_le_bytes());
}

/// Opcode + entry count of a `Batch` response (the server's batched-reply
/// streaming path; pairs with per-entry split writes).
pub fn encode_batch_response_header_into(buf: &mut Vec<u8>, n: usize) {
    buf.push(resp_op::BATCH);
    buf.extend_from_slice(&(n as u32).to_le_bytes());
}

// --- Request codec -----------------------------------------------------------

mod req_op {
    pub const PUT_TENSOR: u8 = 1;
    pub const GET_TENSOR: u8 = 2;
    pub const DEL_TENSOR: u8 = 3;
    pub const EXISTS: u8 = 4;
    pub const PUT_META: u8 = 5;
    pub const GET_META: u8 = 6;
    pub const LIST_KEYS: u8 = 7;
    pub const PUT_MODEL: u8 = 8;
    pub const RUN_MODEL: u8 = 9;
    pub const INFO: u8 = 10;
    pub const FLUSH_ALL: u8 = 11;
    pub const BATCH: u8 = 12;
    pub const MGET_TENSORS: u8 = 13;
    pub const POLL_KEYS: u8 = 14;
    pub const DEL_KEYS: u8 = 15;
    pub const RETENTION: u8 = 16;
    pub const COLD_LIST: u8 = 17;
    pub const COLD_GET: u8 = 18;
    pub const LIST_MODELS: u8 = 19;
    pub const MODEL_STATS: u8 = 20;
    pub const CLUSTER_EPOCH: u8 = 21;
    pub const EXPORT_SLOTS: u8 = 22;
    pub const COLD_PUT: u8 = 23;
}

impl Request {
    pub fn encode(&self, buf: &mut Vec<u8>) {
        match self {
            Request::PutTensor { key, tensor } => {
                buf.push(req_op::PUT_TENSOR);
                put_str(buf, key);
                put_tensor(buf, tensor);
            }
            Request::GetTensor { key } => {
                buf.push(req_op::GET_TENSOR);
                put_str(buf, key);
            }
            Request::DelTensor { key } => {
                buf.push(req_op::DEL_TENSOR);
                put_str(buf, key);
            }
            Request::Exists { key } => {
                buf.push(req_op::EXISTS);
                put_str(buf, key);
            }
            Request::PutMeta { key, value } => {
                buf.push(req_op::PUT_META);
                put_str(buf, key);
                put_str(buf, value);
            }
            Request::GetMeta { key } => {
                buf.push(req_op::GET_META);
                put_str(buf, key);
            }
            Request::ListKeys { prefix } => {
                buf.push(req_op::LIST_KEYS);
                put_str(buf, prefix);
            }
            Request::PutModel { key, hlo_text } => {
                buf.push(req_op::PUT_MODEL);
                put_str(buf, key);
                put_str(buf, hlo_text);
            }
            Request::RunModel { key, version, in_keys, out_keys, device } => {
                buf.push(req_op::RUN_MODEL);
                put_str(buf, key);
                buf.extend_from_slice(&version.to_le_bytes());
                put_str_list(buf, in_keys);
                put_str_list(buf, out_keys);
                put_device(buf, *device);
            }
            Request::Info => buf.push(req_op::INFO),
            Request::FlushAll => buf.push(req_op::FLUSH_ALL),
            Request::Batch(entries) => {
                encode_batch_request_header_into(buf, entries.len());
                for e in entries {
                    e.encode(buf);
                }
            }
            Request::MGetTensors { keys } => {
                buf.push(req_op::MGET_TENSORS);
                put_str_list(buf, keys);
            }
            Request::PollKeys { keys, timeout_ms, initial_us, cap_us } => {
                buf.push(req_op::POLL_KEYS);
                put_str_list(buf, keys);
                buf.extend_from_slice(&timeout_ms.to_le_bytes());
                buf.extend_from_slice(&initial_us.to_le_bytes());
                buf.extend_from_slice(&cap_us.to_le_bytes());
            }
            Request::DelKeys { keys } => {
                buf.push(req_op::DEL_KEYS);
                put_str_list(buf, keys);
            }
            Request::Retention { window, max_bytes, ttl_ms } => {
                buf.push(req_op::RETENTION);
                buf.extend_from_slice(&window.to_le_bytes());
                buf.extend_from_slice(&max_bytes.to_le_bytes());
                buf.extend_from_slice(&ttl_ms.to_le_bytes());
            }
            Request::ColdList { prefix } => {
                buf.push(req_op::COLD_LIST);
                put_str(buf, prefix);
            }
            Request::ColdGet { key } => {
                buf.push(req_op::COLD_GET);
                put_str(buf, key);
            }
            Request::ListModels => buf.push(req_op::LIST_MODELS),
            Request::ModelStats => buf.push(req_op::MODEL_STATS),
            Request::ClusterEpoch { install } => {
                buf.push(req_op::CLUSTER_EPOCH);
                match install {
                    None => buf.push(0),
                    Some((shard, replicas, table)) => {
                        buf.push(1);
                        buf.extend_from_slice(&(*shard as u32).to_le_bytes());
                        buf.extend_from_slice(&(*replicas as u32).to_le_bytes());
                        put_slot_epoch(buf, table);
                    }
                }
            }
            Request::ExportSlots { lo, hi } => {
                buf.push(req_op::EXPORT_SLOTS);
                buf.extend_from_slice(&(*lo as u32).to_le_bytes());
                buf.extend_from_slice(&(*hi as u32).to_le_bytes());
            }
            Request::ColdPut { key, tensor } => {
                buf.push(req_op::COLD_PUT);
                put_str(buf, key);
                put_tensor(buf, tensor);
            }
        }
    }

    /// Decode from a borrowed body; tensor payloads are copied out.
    pub fn decode(body: &[u8]) -> Result<Request> {
        Self::decode_cur(Cur::new(body))
    }

    /// Decode from a shared frame body: tensor payloads become views into
    /// `body` (refcount bump, zero copy).  The caller hands ownership of
    /// the frame buffer to the returned request's tensors; byte-identical
    /// in result to [`Request::decode`].
    pub fn decode_shared(body: &Bytes) -> Result<Request> {
        Self::decode_cur(Cur::shared(body))
    }

    /// Whether decoding this frame body with [`Request::decode_shared`]
    /// would retain a view of it beyond the request's execution (payload-
    /// carrying ops — a bare `PutTensor` or any `Batch`, which may contain
    /// one).  The server uses this to choose between recycling its scratch
    /// read buffer and handing the frame over to the store.
    pub fn frame_holds_payload(body: &[u8]) -> bool {
        matches!(
            body.first(),
            // ColdPut's payload outlives execution too: the spill writer
            // thread holds the bytes until they hit the segment log.
            Some(&req_op::PUT_TENSOR) | Some(&req_op::BATCH) | Some(&req_op::COLD_PUT)
        )
    }

    fn decode_cur(mut c: Cur<'_>) -> Result<Request> {
        let req = Self::decode_one(&mut c, true)?;
        c.done()?;
        Ok(req)
    }

    /// Decode one request off the cursor.  `allow_batch` is cleared for
    /// batch entries so nesting stops at one level.
    fn decode_one(c: &mut Cur<'_>, allow_batch: bool) -> Result<Request> {
        let op = c.u8()?;
        let req = match op {
            req_op::PUT_TENSOR => Request::PutTensor { key: c.str()?, tensor: c.tensor()? },
            req_op::GET_TENSOR => Request::GetTensor { key: c.str()? },
            req_op::DEL_TENSOR => Request::DelTensor { key: c.str()? },
            req_op::EXISTS => Request::Exists { key: c.str()? },
            req_op::PUT_META => Request::PutMeta { key: c.str()?, value: c.str()? },
            req_op::GET_META => Request::GetMeta { key: c.str()? },
            req_op::LIST_KEYS => Request::ListKeys { prefix: c.str()? },
            req_op::PUT_MODEL => Request::PutModel { key: c.str()?, hlo_text: c.str()? },
            req_op::RUN_MODEL => {
                let key = c.str()?;
                let version = c.u64()?;
                let n_in = c.u32()? as usize;
                if n_in > 4096 {
                    return Err(Error::Protocol("too many input keys".into()));
                }
                let mut in_keys = Vec::with_capacity(n_in);
                for _ in 0..n_in {
                    in_keys.push(c.str()?);
                }
                let n_out = c.u32()? as usize;
                if n_out > 4096 {
                    return Err(Error::Protocol("too many output keys".into()));
                }
                let mut out_keys = Vec::with_capacity(n_out);
                for _ in 0..n_out {
                    out_keys.push(c.str()?);
                }
                let device = device_from_byte(c.u8()?)?;
                Request::RunModel { key, version, in_keys, out_keys, device }
            }
            req_op::INFO => Request::Info,
            req_op::FLUSH_ALL => Request::FlushAll,
            req_op::BATCH => {
                if !allow_batch {
                    return Err(Error::Protocol("nested batch request".into()));
                }
                let n = c.u32()? as usize;
                if n > MAX_BATCH {
                    return Err(Error::Protocol(format!("batch of {n} exceeds {MAX_BATCH}")));
                }
                let mut entries = Vec::with_capacity(n);
                for _ in 0..n {
                    entries.push(Self::decode_one(c, false)?);
                }
                Request::Batch(entries)
            }
            req_op::MGET_TENSORS => Request::MGetTensors { keys: c.str_list()? },
            req_op::POLL_KEYS => Request::PollKeys {
                keys: c.str_list()?,
                timeout_ms: c.u64()?,
                initial_us: c.u64()?,
                cap_us: c.u64()?,
            },
            req_op::DEL_KEYS => Request::DelKeys { keys: c.str_list()? },
            req_op::RETENTION => Request::Retention {
                window: c.u64()?,
                max_bytes: c.u64()?,
                ttl_ms: c.u64()?,
            },
            req_op::COLD_LIST => Request::ColdList { prefix: c.str()? },
            req_op::COLD_GET => Request::ColdGet { key: c.str()? },
            req_op::LIST_MODELS => Request::ListModels,
            req_op::MODEL_STATS => Request::ModelStats,
            req_op::CLUSTER_EPOCH => {
                let install = match c.u8()? {
                    0 => None,
                    1 => {
                        let shard = c.u32()?;
                        if shard > u16::MAX as u32 {
                            return Err(Error::Protocol(format!("bad shard index {shard}")));
                        }
                        let replicas = c.u32()?;
                        if replicas == 0 || replicas > u16::MAX as u32 {
                            return Err(Error::Protocol(format!("bad replica count {replicas}")));
                        }
                        let table = c.slot_epoch()?;
                        if table.assignments.is_empty() {
                            return Err(Error::Protocol("cannot install an empty table".into()));
                        }
                        Some((shard as u16, replicas as u16, table))
                    }
                    f => return Err(Error::Protocol(format!("bad install flag {f}"))),
                };
                Request::ClusterEpoch { install }
            }
            req_op::EXPORT_SLOTS => {
                let lo = c.u32()?;
                let hi = c.u32()?;
                if lo >= N_SLOTS as u32 || hi >= N_SLOTS as u32 || lo > hi {
                    return Err(Error::Protocol(format!("bad slot range {lo}..={hi}")));
                }
                Request::ExportSlots { lo: lo as u16, hi: hi as u16 }
            }
            req_op::COLD_PUT => Request::ColdPut { key: c.str()?, tensor: c.tensor()? },
            _ => return Err(Error::Protocol(format!("unknown request opcode {op}"))),
        };
        Ok(req)
    }

    /// The key this command routes on in a sharded deployment, if it acts
    /// on exactly one key of the replicated data plane.  `None` for
    /// whole-database and multi-key commands, and for model ops: models
    /// live in each shard's private runtime and must be *broadcast* (the
    /// cluster client's `put_model`), so routing a pipelined upload to one
    /// shard would silently break `run_model` on the others.
    pub fn routing_key(&self) -> Option<&str> {
        match self {
            Request::PutTensor { key, .. }
            | Request::GetTensor { key }
            | Request::DelTensor { key }
            | Request::Exists { key }
            | Request::PutMeta { key, .. }
            | Request::GetMeta { key }
            // A key spills on the shard that evicted it — the shard it
            // routes to — so cold reads route exactly like hot ones.
            | Request::ColdGet { key } => Some(key),
            Request::ListKeys { .. }
            | Request::PutModel { .. }
            | Request::RunModel { .. }
            | Request::Info
            | Request::FlushAll
            | Request::Batch(_)
            | Request::MGetTensors { .. }
            | Request::PollKeys { .. }
            | Request::DelKeys { .. }
            | Request::Retention { .. }
            | Request::ColdList { .. }
            | Request::ListModels
            | Request::ModelStats => None,
            // Control-plane and transfer ops are driver-directed at a
            // specific shard (`on_shard`), never slot-routed: ColdPut in
            // particular deliberately lands on the retirement anchor, not
            // wherever its key would currently hash.
            Request::ClusterEpoch { .. }
            | Request::ExportSlots { .. }
            | Request::ColdPut { .. } => None,
        }
    }

    /// Exact encoded body size (opcode + fields, no frame prefix), computed
    /// arithmetically — the client's batched send path uses this to declare
    /// the frame length without materializing any payload.
    pub fn body_wire_size(&self) -> usize {
        let fields = match self {
            Request::PutTensor { key, tensor } => str_wire_size(key) + tensor_wire_size(tensor),
            Request::GetTensor { key }
            | Request::DelTensor { key }
            | Request::Exists { key }
            | Request::GetMeta { key } => str_wire_size(key),
            Request::PutMeta { key, value } => str_wire_size(key) + str_wire_size(value),
            Request::ListKeys { prefix } => str_wire_size(prefix),
            Request::PutModel { key, hlo_text } => str_wire_size(key) + str_wire_size(hlo_text),
            Request::RunModel { key, in_keys, out_keys, .. } => {
                str_wire_size(key)
                    + 8
                    + str_list_wire_size(in_keys)
                    + str_list_wire_size(out_keys)
                    + 1
            }
            Request::Info | Request::FlushAll | Request::ListModels | Request::ModelStats => 0,
            Request::Batch(entries) => {
                4 + entries.iter().map(|e| e.body_wire_size()).sum::<usize>()
            }
            Request::MGetTensors { keys } => str_list_wire_size(keys),
            Request::PollKeys { keys, .. } => str_list_wire_size(keys) + 24,
            Request::DelKeys { keys } => str_list_wire_size(keys),
            Request::Retention { .. } => 24,
            Request::ColdList { prefix } => str_wire_size(prefix),
            Request::ColdGet { key } => str_wire_size(key),
            Request::ClusterEpoch { install } => match install {
                None => 1,
                Some((_, _, table)) => 1 + 4 + 4 + slot_epoch_wire_size(table),
            },
            Request::ExportSlots { .. } => 8,
            Request::ColdPut { key, tensor } => str_wire_size(key) + tensor_wire_size(tensor),
        };
        1 + fields // opcode + fields
    }

    /// Exact wire size including the 4-byte frame prefix, computed
    /// arithmetically (used by the DES cost model and stats; previously
    /// this encoded the whole message — copying the full payload — just to
    /// count bytes).
    pub fn wire_size(&self) -> usize {
        4 + self.body_wire_size()
    }
}

// --- Response codec ----------------------------------------------------------

mod resp_op {
    pub const OK: u8 = 1;
    pub const TENSOR: u8 = 2;
    pub const NOT_FOUND: u8 = 3;
    pub const BOOL: u8 = 4;
    pub const META: u8 = 5;
    pub const KEYS: u8 = 6;
    pub const ERROR: u8 = 7;
    pub const INFO: u8 = 8;
    pub const BATCH: u8 = 9;
    pub const MODELS: u8 = 10;
    pub const MODEL_STATS: u8 = 11;
    pub const VERSION: u8 = 12;
    pub const EPOCH_TABLE: u8 = 13;
}

impl Response {
    pub fn encode(&self, buf: &mut Vec<u8>) {
        match self {
            Response::Ok => buf.push(resp_op::OK),
            Response::Tensor(t) => {
                buf.push(resp_op::TENSOR);
                put_tensor(buf, t);
            }
            Response::NotFound => buf.push(resp_op::NOT_FOUND),
            Response::Bool(b) => {
                buf.push(resp_op::BOOL);
                buf.push(*b as u8);
            }
            Response::Meta(s) => {
                buf.push(resp_op::META);
                put_str(buf, s);
            }
            Response::Keys(ks) => {
                buf.push(resp_op::KEYS);
                buf.extend_from_slice(&(ks.len() as u32).to_le_bytes());
                for k in ks {
                    put_str(buf, k);
                }
            }
            Response::Error(m) => {
                buf.push(resp_op::ERROR);
                put_str(buf, m);
            }
            Response::Info(i) => {
                buf.push(resp_op::INFO);
                buf.extend_from_slice(&i.keys.to_le_bytes());
                buf.extend_from_slice(&i.bytes.to_le_bytes());
                buf.extend_from_slice(&i.ops.to_le_bytes());
                buf.extend_from_slice(&i.models.to_le_bytes());
                buf.extend_from_slice(&i.high_water_bytes.to_le_bytes());
                buf.extend_from_slice(&i.evicted_keys.to_le_bytes());
                buf.extend_from_slice(&i.evicted_bytes.to_le_bytes());
                buf.extend_from_slice(&i.busy_rejections.to_le_bytes());
                buf.extend_from_slice(&i.ttl_expired_keys.to_le_bytes());
                buf.extend_from_slice(&i.retention_window.to_le_bytes());
                buf.extend_from_slice(&i.retention_max_bytes.to_le_bytes());
                buf.extend_from_slice(&i.retention_ttl_ms.to_le_bytes());
                buf.extend_from_slice(&i.spilled_keys.to_le_bytes());
                buf.extend_from_slice(&i.spilled_bytes.to_le_bytes());
                buf.extend_from_slice(&i.spill_segments.to_le_bytes());
                buf.extend_from_slice(&i.cold_hits.to_le_bytes());
                buf.extend_from_slice(&i.spill_lost_keys.to_le_bytes());
                buf.extend_from_slice(&i.replicated_writes.to_le_bytes());
                buf.extend_from_slice(&i.read_failovers.to_le_bytes());
                buf.extend_from_slice(&i.shard_reconnects.to_le_bytes());
                buf.extend_from_slice(&i.degraded_ops.to_le_bytes());
                buf.extend_from_slice(&i.model_swaps.to_le_bytes());
                buf.extend_from_slice(&i.batches.to_le_bytes());
                buf.extend_from_slice(&i.batched_requests.to_le_bytes());
                put_str(buf, &i.engine);
                buf.extend_from_slice(&(i.fields.len() as u32).to_le_bytes());
                for f in &i.fields {
                    put_str(buf, &f.field);
                    buf.extend_from_slice(&f.resident_bytes.to_le_bytes());
                    buf.extend_from_slice(&f.generations.to_le_bytes());
                    buf.extend_from_slice(&f.evicted_keys.to_le_bytes());
                    buf.extend_from_slice(&f.evicted_bytes.to_le_bytes());
                    buf.extend_from_slice(&f.spilled_keys.to_le_bytes());
                    buf.extend_from_slice(&f.spilled_bytes.to_le_bytes());
                }
            }
            Response::Batch(entries) => {
                encode_batch_response_header_into(buf, entries.len());
                for e in entries {
                    e.encode(buf);
                }
            }
            Response::Models(ms) => {
                buf.push(resp_op::MODELS);
                buf.extend_from_slice(&(ms.len() as u32).to_le_bytes());
                for m in ms {
                    put_str(buf, &m.key);
                    buf.extend_from_slice(&m.live_version.to_le_bytes());
                    buf.extend_from_slice(&m.n_versions.to_le_bytes());
                    buf.extend_from_slice(&m.swaps.to_le_bytes());
                    buf.extend_from_slice(&m.executions.to_le_bytes());
                }
            }
            Response::ModelStats(ds) => {
                buf.push(resp_op::MODEL_STATS);
                buf.extend_from_slice(&(ds.len() as u32).to_le_bytes());
                for d in ds {
                    put_device(buf, d.device);
                    buf.extend_from_slice(&d.executions.to_le_bytes());
                    buf.extend_from_slice(&d.eval_count.to_le_bytes());
                    buf.extend_from_slice(&d.eval_mean_s.to_bits().to_le_bytes());
                    buf.extend_from_slice(&d.eval_std_s.to_bits().to_le_bytes());
                    buf.extend_from_slice(&d.queue_count.to_le_bytes());
                    buf.extend_from_slice(&d.queue_mean_s.to_bits().to_le_bytes());
                    buf.extend_from_slice(&d.queue_std_s.to_bits().to_le_bytes());
                }
            }
            Response::Version(v) => {
                buf.push(resp_op::VERSION);
                buf.extend_from_slice(&v.to_le_bytes());
            }
            Response::EpochTable { shard, table } => {
                buf.push(resp_op::EPOCH_TABLE);
                let s = if *shard == u16::MAX { u32::MAX } else { *shard as u32 };
                buf.extend_from_slice(&s.to_le_bytes());
                put_slot_epoch(buf, table);
            }
        }
    }

    /// Decode from a borrowed body; tensor payloads are copied out.
    pub fn decode(body: &[u8]) -> Result<Response> {
        Self::decode_cur(Cur::new(body))
    }

    /// Decode from a shared frame body: a tensor reply aliases `body`
    /// instead of copying the payload (the client's `get_tensor` hot path).
    /// Every tensor inside a `Batch` reply aliases the same frame body, so
    /// a batched gather still costs one allocation total.
    pub fn decode_shared(body: &Bytes) -> Result<Response> {
        Self::decode_cur(Cur::shared(body))
    }

    fn decode_cur(mut c: Cur<'_>) -> Result<Response> {
        let resp = Self::decode_one(&mut c, true)?;
        c.done()?;
        Ok(resp)
    }

    fn decode_one(c: &mut Cur<'_>, allow_batch: bool) -> Result<Response> {
        let op = c.u8()?;
        let resp = match op {
            resp_op::OK => Response::Ok,
            resp_op::TENSOR => Response::Tensor(c.tensor()?),
            resp_op::NOT_FOUND => Response::NotFound,
            resp_op::BOOL => Response::Bool(c.u8()? != 0),
            resp_op::META => Response::Meta(c.str()?),
            resp_op::KEYS => {
                let n = c.u32()? as usize;
                if n > 1 << 20 {
                    return Err(Error::Protocol("too many keys".into()));
                }
                let mut ks = Vec::with_capacity(n.min(1024));
                for _ in 0..n {
                    ks.push(c.str()?);
                }
                Response::Keys(ks)
            }
            resp_op::ERROR => Response::Error(c.str()?),
            resp_op::INFO => {
                let keys = c.u64()?;
                let bytes = c.u64()?;
                let ops = c.u64()?;
                let models = c.u64()?;
                let high_water_bytes = c.u64()?;
                let evicted_keys = c.u64()?;
                let evicted_bytes = c.u64()?;
                let busy_rejections = c.u64()?;
                let ttl_expired_keys = c.u64()?;
                let retention_window = c.u64()?;
                let retention_max_bytes = c.u64()?;
                let retention_ttl_ms = c.u64()?;
                let spilled_keys = c.u64()?;
                let spilled_bytes = c.u64()?;
                let spill_segments = c.u64()?;
                let cold_hits = c.u64()?;
                let spill_lost_keys = c.u64()?;
                let replicated_writes = c.u64()?;
                let read_failovers = c.u64()?;
                let shard_reconnects = c.u64()?;
                let degraded_ops = c.u64()?;
                let model_swaps = c.u64()?;
                let batches = c.u64()?;
                let batched_requests = c.u64()?;
                let engine = c.str()?;
                let n = c.u32()? as usize;
                if n > MAX_BATCH {
                    return Err(Error::Protocol(format!(
                        "field pressure list of {n} exceeds {MAX_BATCH}"
                    )));
                }
                let mut fields = Vec::with_capacity(n);
                for _ in 0..n {
                    fields.push(FieldPressure {
                        field: c.str()?,
                        resident_bytes: c.u64()?,
                        generations: c.u64()?,
                        evicted_keys: c.u64()?,
                        evicted_bytes: c.u64()?,
                        spilled_keys: c.u64()?,
                        spilled_bytes: c.u64()?,
                    });
                }
                Response::Info(DbInfo {
                    keys,
                    bytes,
                    ops,
                    models,
                    high_water_bytes,
                    evicted_keys,
                    evicted_bytes,
                    busy_rejections,
                    ttl_expired_keys,
                    retention_window,
                    retention_max_bytes,
                    retention_ttl_ms,
                    spilled_keys,
                    spilled_bytes,
                    spill_segments,
                    cold_hits,
                    spill_lost_keys,
                    replicated_writes,
                    read_failovers,
                    shard_reconnects,
                    degraded_ops,
                    model_swaps,
                    batches,
                    batched_requests,
                    engine,
                    fields,
                })
            }
            resp_op::BATCH => {
                if !allow_batch {
                    return Err(Error::Protocol("nested batch response".into()));
                }
                let n = c.u32()? as usize;
                if n > MAX_BATCH {
                    return Err(Error::Protocol(format!("batch of {n} exceeds {MAX_BATCH}")));
                }
                let mut entries = Vec::with_capacity(n);
                for _ in 0..n {
                    entries.push(Self::decode_one(c, false)?);
                }
                Response::Batch(entries)
            }
            resp_op::MODELS => {
                let n = c.u32()? as usize;
                if n > MAX_BATCH {
                    return Err(Error::Protocol(format!("model list of {n} exceeds {MAX_BATCH}")));
                }
                let mut ms = Vec::with_capacity(n);
                for _ in 0..n {
                    ms.push(ModelEntry {
                        key: c.str()?,
                        live_version: c.u64()?,
                        n_versions: c.u64()?,
                        swaps: c.u64()?,
                        executions: c.u64()?,
                    });
                }
                Response::Models(ms)
            }
            resp_op::MODEL_STATS => {
                let n = c.u32()? as usize;
                // CPU + at most 16 GPU ordinals per node.
                if n > 17 {
                    return Err(Error::Protocol(format!("device stat list of {n} exceeds 17")));
                }
                let mut ds = Vec::with_capacity(n);
                for _ in 0..n {
                    ds.push(ModelDeviceStat {
                        device: device_from_byte(c.u8()?)?,
                        executions: c.u64()?,
                        eval_count: c.u64()?,
                        eval_mean_s: c.f64()?,
                        eval_std_s: c.f64()?,
                        queue_count: c.u64()?,
                        queue_mean_s: c.f64()?,
                        queue_std_s: c.f64()?,
                    });
                }
                Response::ModelStats(ds)
            }
            resp_op::VERSION => Response::Version(c.u64()?),
            resp_op::EPOCH_TABLE => {
                let s = c.u32()?;
                let shard = if s == u32::MAX {
                    u16::MAX
                } else if s < u16::MAX as u32 {
                    s as u16
                } else {
                    return Err(Error::Protocol(format!("bad shard index {s}")));
                };
                Response::EpochTable { shard, table: c.slot_epoch()? }
            }
            _ => return Err(Error::Protocol(format!("unknown response opcode {op}"))),
        };
        Ok(resp)
    }

    /// Exact encoded body size (opcode + fields, no frame prefix) — the
    /// server's streaming reply path uses this to declare the frame length
    /// without materializing tensor payloads in an output buffer.
    pub fn body_wire_size(&self) -> usize {
        let fields = match self {
            Response::Ok | Response::NotFound => 0,
            Response::Tensor(t) => tensor_wire_size(t),
            Response::Bool(_) => 1,
            Response::Meta(s) | Response::Error(s) => str_wire_size(s),
            Response::Keys(ks) => 4 + ks.iter().map(|k| str_wire_size(k)).sum::<usize>(),
            Response::Info(i) => {
                // 24 fixed u64 counters precede the engine string.
                192 + str_wire_size(&i.engine)
                    + 4
                    + i.fields
                        .iter()
                        .map(|f| str_wire_size(&f.field) + 48)
                        .sum::<usize>()
            }
            Response::Batch(entries) => {
                4 + entries.iter().map(|e| e.body_wire_size()).sum::<usize>()
            }
            Response::Models(ms) => {
                4 + ms.iter().map(|m| str_wire_size(&m.key) + 32).sum::<usize>()
            }
            // 1 device byte + 7 u64/f64 fields per row.
            Response::ModelStats(ds) => 4 + ds.len() * 57,
            Response::Version(_) => 8,
            Response::EpochTable { table, .. } => 4 + slot_epoch_wire_size(table),
        };
        1 + fields
    }
}

// --- typed response conversions ---------------------------------------------
//
// Every client-side `match`-on-`Response` used to be hand-rolled per method;
// the `expect_*` family centralizes the conversion (remote errors become
// `Error::Remote`, anything else unexpected becomes `Error::Protocol`), so
// both `Client` and `ClusterClient` — and user code consuming batch replies —
// share one conversion layer.

impl Response {
    fn unexpected(self, want: &str) -> Error {
        match self {
            // Backpressure travels the wire as an error string with the
            // `busy: ` prefix (`Error::Busy`'s Display); map it back so
            // producers can distinguish "retry later" from real failures.
            Response::Error(m) => match m.strip_prefix("busy: ") {
                Some(rest) => Error::Busy(rest.to_string()),
                // A shard rejecting a slot it no longer owns reports the
                // epoch it is at; the cluster client refetches and retries.
                None => match m.strip_prefix("moved: ").and_then(|r| r.parse::<u64>().ok()) {
                    Some(epoch) => Error::Moved(epoch),
                    None => Error::Remote(m),
                },
            },
            other => Error::Protocol(format!("expected {want}, got {other:?}")),
        }
    }

    /// `Ok` → `()`.
    pub fn expect_ok(self) -> Result<()> {
        match self {
            Response::Ok => Ok(()),
            other => Err(other.unexpected("Ok")),
        }
    }

    /// `Tensor` → the tensor; `NotFound` → `Error::KeyNotFound(key)`.
    pub fn expect_tensor(self, key: &str) -> Result<Tensor> {
        match self {
            Response::Tensor(t) => Ok(t),
            Response::NotFound => Err(Error::KeyNotFound(key.to_string())),
            other => Err(other.unexpected("Tensor")),
        }
    }

    /// Deletion result: `Ok` → `true`, `NotFound` → `false`.
    pub fn expect_deleted(self) -> Result<bool> {
        match self {
            Response::Ok => Ok(true),
            Response::NotFound => Ok(false),
            other => Err(other.unexpected("Ok|NotFound")),
        }
    }

    /// `Bool` → the flag.
    pub fn expect_bool(self) -> Result<bool> {
        match self {
            Response::Bool(b) => Ok(b),
            other => Err(other.unexpected("Bool")),
        }
    }

    /// `Meta` → `Some(value)`, `NotFound` → `None`.
    pub fn expect_meta(self) -> Result<Option<String>> {
        match self {
            Response::Meta(v) => Ok(Some(v)),
            Response::NotFound => Ok(None),
            other => Err(other.unexpected("Meta")),
        }
    }

    /// `Keys` → the key list.
    pub fn expect_keys(self) -> Result<Vec<String>> {
        match self {
            Response::Keys(ks) => Ok(ks),
            other => Err(other.unexpected("Keys")),
        }
    }

    /// `Info` → the stats struct.
    pub fn expect_info(self) -> Result<DbInfo> {
        match self {
            Response::Info(i) => Ok(i),
            other => Err(other.unexpected("Info")),
        }
    }

    /// `Version` → the version number a `PutModel` assigned.
    pub fn expect_version(self) -> Result<u64> {
        match self {
            Response::Version(v) => Ok(v),
            other => Err(other.unexpected("Version")),
        }
    }

    /// `Models` → the registry listing.
    pub fn expect_models(self) -> Result<Vec<ModelEntry>> {
        match self {
            Response::Models(ms) => Ok(ms),
            other => Err(other.unexpected("Models")),
        }
    }

    /// `ModelStats` → the per-device statistics rows.
    pub fn expect_model_stats(self) -> Result<Vec<ModelDeviceStat>> {
        match self {
            Response::ModelStats(ds) => Ok(ds),
            other => Err(other.unexpected("ModelStats")),
        }
    }

    /// `EpochTable` → `(shard, table)` (`shard == u16::MAX` when the
    /// server has no installed identity).
    pub fn expect_epoch_table(self) -> Result<(u16, SlotEpoch)> {
        match self {
            Response::EpochTable { shard, table } => Ok((shard, table)),
            other => Err(other.unexpected("EpochTable")),
        }
    }

    /// `Batch` → the per-entry results, checked against the request count.
    pub fn expect_batch(self, expected: usize) -> Result<Vec<Response>> {
        match self {
            Response::Batch(entries) if entries.len() == expected => Ok(entries),
            Response::Batch(entries) => Err(Error::Protocol(format!(
                "batch reply has {} entries, expected {expected}",
                entries.len()
            ))),
            other => Err(other.unexpected("Batch")),
        }
    }
}
