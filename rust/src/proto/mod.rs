//! Wire protocol between SmartRedis-analogue clients and the tensor database.
//!
//! The paper's client library speaks RESP to Redis/KeyDB; we define an
//! equivalent compact binary protocol:
//!
//! ```text
//! frame   := u32-LE body_len | body
//! body    := u8 opcode | fields...
//! string  := u32-LE len | utf8 bytes
//! tensor  := u8 dtype | u8 ndim | u32-LE dims[ndim] | payload bytes
//! ```
//!
//! Requests and responses are symmetric frames.  The protocol is strictly
//! request/response per connection (like RESP without pipelining; clients
//! that want concurrency open more connections, exactly how the paper runs
//! one SmartRedis client per simulation rank).

pub mod frame;
pub mod message;

pub use frame::{read_frame, write_frame, MAX_FRAME};
pub use message::{Device, Request, Response};

#[cfg(test)]
mod tests {
    use super::message::*;
    use crate::tensor::{DType, Tensor};
    use crate::util::propcheck::{check, Gen};

    fn roundtrip_req(r: &Request) -> Request {
        let mut buf = Vec::new();
        r.encode(&mut buf);
        Request::decode(&buf).expect("decode")
    }

    fn roundtrip_resp(r: &Response) -> Response {
        let mut buf = Vec::new();
        r.encode(&mut buf);
        Response::decode(&buf).expect("decode")
    }

    #[test]
    fn request_roundtrips() {
        let t = Tensor::from_f32(&[2, 2], vec![1.0, -2.5, 3.0, 0.0]).unwrap();
        let cases = vec![
            Request::PutTensor { key: "f_rank0_step2".into(), tensor: t.clone() },
            Request::GetTensor { key: "k".into() },
            Request::DelTensor { key: "k".into() },
            Request::Exists { key: "k".into() },
            Request::PutMeta { key: "m".into(), value: "epoch=3".into() },
            Request::GetMeta { key: "m".into() },
            Request::ListKeys { prefix: "f_".into() },
            Request::PutModel { key: "enc".into(), hlo_text: "HloModule m".into() },
            Request::RunModel {
                key: "enc".into(),
                in_keys: vec!["a".into(), "b".into()],
                out_keys: vec!["z".into()],
                device: Device::Gpu(2),
            },
            Request::Info,
            Request::FlushAll,
        ];
        for c in cases {
            assert_eq!(roundtrip_req(&c), c);
        }
    }

    #[test]
    fn response_roundtrips() {
        let t = Tensor::from_i32(&[3], vec![1, 2, 3]).unwrap();
        let cases = vec![
            Response::Ok,
            Response::Tensor(t),
            Response::NotFound,
            Response::Bool(true),
            Response::Meta("x".into()),
            Response::Keys(vec!["a".into(), "b".into()]),
            Response::Error("boom".into()),
            Response::Info { keys: 10, bytes: 1 << 20, ops: 42, models: 2, engine: "redis".into() },
        ];
        for c in cases {
            assert_eq!(roundtrip_resp(&c), c);
        }
    }

    #[test]
    fn borrowed_put_tensor_encoding_is_byte_identical() {
        let t = Tensor::from_f32(&[3, 2], vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]).unwrap();
        let owned = Request::PutTensor { key: "k1".into(), tensor: t.clone() };
        let mut a = Vec::new();
        owned.encode(&mut a);
        let mut b = Vec::new();
        encode_put_tensor_into(&mut b, "k1", &t);
        assert_eq!(a, b);
    }

    #[test]
    fn decode_rejects_garbage() {
        assert!(Request::decode(&[]).is_err());
        assert!(Request::decode(&[0xff]).is_err());
        // Truncated string length.
        assert!(Request::decode(&[1, 4, 0, 0]).is_err());
        // String body shorter than its declared length.
        assert!(Request::decode(&[1, 4, 0, 0, 0, b'a']).is_err());
    }

    #[test]
    fn prop_arbitrary_tensor_roundtrip() {
        check("proto tensor roundtrip", 200, |g: &mut Gen| {
            let ndim = g.usize_in(0..=4);
            let shape: Vec<usize> = (0..ndim).map(|_| g.usize_in(1..=8)).collect();
            let n: usize = shape.iter().product();
            let dt = *g.choose(&[DType::F32, DType::I32, DType::U8, DType::F64]);
            let data: Vec<u8> = (0..n * dt.size()).map(|_| g.u32() as u8).collect();
            let t = Tensor { dtype: dt, shape, data };
            let r = Request::PutTensor { key: g.key(), tensor: t };
            assert_eq!(roundtrip_req(&r), r);
        });
    }

    #[test]
    fn prop_decode_never_panics_on_fuzz() {
        // Malformed bytes must produce Err, never a panic/abort.
        check("proto fuzz decode", 500, |g: &mut Gen| {
            let bytes = g.vec(0..=64, |g| g.u32() as u8);
            let _ = Request::decode(&bytes);
            let _ = Response::decode(&bytes);
        });
    }

    #[test]
    fn prop_mutated_valid_frame_never_panics() {
        check("proto mutation decode", 300, |g: &mut Gen| {
            let t = Tensor::from_f32(&[2], vec![1.0, 2.0]).unwrap();
            let r = Request::RunModel {
                key: g.key(),
                in_keys: vec![g.key(), g.key()],
                out_keys: vec![g.key()],
                device: Device::Cpu,
            };
            let mut buf = Vec::new();
            r.encode(&mut buf);
            let r2 = Request::PutTensor { key: g.key(), tensor: t };
            r2.encode(&mut buf);
            // Flip a few bytes.
            for _ in 0..g.usize_in(1..=8) {
                let i = g.usize_in(0..=buf.len() - 1);
                buf[i] ^= g.u32() as u8;
            }
            let _ = Request::decode(&buf);
        });
    }
}
