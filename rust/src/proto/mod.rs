//! Wire protocol between SmartRedis-analogue clients and the tensor database.
//!
//! The paper's client library speaks RESP to Redis/KeyDB; we define an
//! equivalent compact binary protocol:
//!
//! ```text
//! frame   := u32-LE body_len | body                      (legacy, tag 0)
//! frame   := u32-LE (body_len|TAG) | u32-LE tag | body   (tagged, TAG = bit 31)
//! body    := u8 opcode | fields...
//! string  := u32-LE len | utf8 bytes
//! tensor  := u8 dtype | u8 ndim | u32-LE dims[ndim] | u64-LE payload_len | payload bytes
//! ```
//!
//! Requests and responses are symmetric frames.  An *untagged* frame is
//! the legacy strict request/response round trip (one SmartRedis client
//! per simulation rank, as in the paper).  A *tagged* frame — length word
//! with [`frame::FRAME_TAG_FLAG`] set, followed by a nonzero u32 tag —
//! multiplexes: one socket carries many in-flight requests whose replies
//! may return out of order, each echoing its request's tag.  Tag 0 is
//! reserved to mean "untagged" and encodes as the legacy format
//! byte-for-byte, so old clients and servers interoperate unchanged.
//!
//! Pipelining also happens *inside* a frame: a [`Request::Batch`] carries
//! many commands and is answered by one [`Response::Batch`] with
//! per-entry results, and the [`Request::MGetTensors`] /
//! [`Request::PollKeys`] fast paths collapse the dataloader's per-epoch
//! gather and wait loops to one round trip each.
//!
//! ## Zero-copy data plane
//!
//! Tensor payloads never make an avoidable copy between the socket and the
//! store (or back):
//!
//! * **Ingress** — the server reads each frame with
//!   [`frame::read_frame_into`] into a per-connection scratch buffer.  For
//!   payload-carrying frames ([`Request::frame_holds_payload`]) the buffer
//!   is handed over wholesale as a shared [`crate::tensor::Bytes`] and
//!   [`Request::decode_shared`] yields a tensor whose payload is a *view*
//!   into it; the store then keeps that one allocation alive by refcount.
//! * **Egress** — a tensor reply is written as a split frame
//!   ([`frame::begin_split_frame`]/[`frame::end_split_frame`]): a few
//!   header bytes are copied, the payload goes from the store's buffer
//!   straight to the socket.
//! * **Client** — `put_tensor` uses the same split-frame write from the
//!   borrowed tensor; `get_tensor` decodes the reply with
//!   [`Response::decode_shared`], aliasing the frame it just read.

pub mod frame;
pub mod message;

pub use frame::{begin_split_frame, end_split_frame, read_frame, read_frame_into,
                read_frame_into_tagged, write_frame, write_tagged_frame, FrameSink,
                FRAME_TAG_FLAG, MAX_FRAME};
pub use message::{
    DbInfo, Device, FieldPressure, ModelDeviceStat, ModelEntry, Request, Response, MAX_BATCH,
};

#[cfg(test)]
mod tests {
    use super::message::*;
    use crate::db::cluster::{SlotAssign, SlotEpoch, N_SLOTS};
    use crate::tensor::{Bytes, DType, Tensor};
    use crate::util::propcheck::{check, Gen};

    /// A small but structurally valid epoch table: three shards, the middle
    /// range mid-migration (shard 2 taking over from shard 1).
    fn sample_table() -> SlotEpoch {
        SlotEpoch {
            epoch: 7,
            assignments: vec![
                SlotAssign { lo: 0, hi: 5000, shard: 0, from: None },
                SlotAssign { lo: 5001, hi: 11000, shard: 2, from: Some(1) },
                SlotAssign { lo: 11001, hi: N_SLOTS - 1, shard: 2, from: None },
            ],
        }
    }

    fn roundtrip_req(r: &Request) -> Request {
        let mut buf = Vec::new();
        r.encode(&mut buf);
        Request::decode(&buf).expect("decode")
    }

    fn roundtrip_resp(r: &Response) -> Response {
        let mut buf = Vec::new();
        r.encode(&mut buf);
        Response::decode(&buf).expect("decode")
    }

    fn all_request_variants() -> Vec<Request> {
        let t = Tensor::from_f32(&[2, 2], vec![1.0, -2.5, 3.0, 0.0]).unwrap();
        vec![
            Request::PutTensor { key: "f_rank0_step2".into(), tensor: t },
            Request::GetTensor { key: "k".into() },
            Request::DelTensor { key: "k".into() },
            Request::Exists { key: "k".into() },
            Request::PutMeta { key: "m".into(), value: "epoch=3".into() },
            Request::GetMeta { key: "m".into() },
            Request::ListKeys { prefix: "f_".into() },
            Request::PutModel { key: "enc".into(), hlo_text: "HloModule m".into() },
            Request::RunModel {
                key: "enc".into(),
                version: 0,
                in_keys: vec!["a".into(), "b".into()],
                out_keys: vec!["z".into()],
                device: Device::Gpu(2),
            },
            Request::RunModel {
                key: "enc".into(),
                version: 7,
                in_keys: vec!["a".into()],
                out_keys: vec!["z".into()],
                device: Device::Cpu,
            },
            Request::Info,
            Request::FlushAll,
            Request::Batch(vec![
                Request::PutTensor {
                    key: "b0".into(),
                    tensor: Tensor::from_f32(&[3], vec![0.5, 1.5, 2.5]).unwrap(),
                },
                Request::GetTensor { key: "b1".into() },
                Request::Exists { key: "b2".into() },
            ]),
            Request::MGetTensors { keys: vec!["m0".into(), "m1".into()] },
            Request::PollKeys {
                keys: vec!["p0".into(), "p1".into()],
                timeout_ms: 1500,
                initial_us: 500,
                cap_us: 20_000,
            },
            Request::DelKeys { keys: vec!["d0".into(), "d1".into(), "d2".into()] },
            Request::Retention { window: 4, max_bytes: 1 << 28, ttl_ms: 30_000 },
            Request::ColdList { prefix: "f_".into() },
            Request::ColdGet { key: "f_rank0_step0".into() },
            Request::ListModels,
            Request::ModelStats,
            Request::ClusterEpoch { install: None },
            Request::ClusterEpoch { install: Some((2, 2, sample_table())) },
            Request::ExportSlots { lo: 5001, hi: 11000 },
            Request::ColdPut {
                key: "f_rank0_step1".into(),
                tensor: Tensor::from_f32(&[2], vec![4.0, 5.0]).unwrap(),
            },
        ]
    }

    #[test]
    fn request_roundtrips() {
        for c in all_request_variants() {
            assert_eq!(roundtrip_req(&c), c);
        }
    }

    fn all_response_variants() -> Vec<Response> {
        let t = Tensor::from_i32(&[3], vec![1, 2, 3]).unwrap();
        vec![
            Response::Ok,
            Response::Tensor(t.clone()),
            Response::NotFound,
            Response::Bool(true),
            Response::Meta("x".into()),
            Response::Keys(vec!["a".into(), "b".into()]),
            Response::Error("boom".into()),
            Response::Info(DbInfo {
                keys: 10,
                bytes: 1 << 20,
                ops: 42,
                models: 2,
                high_water_bytes: 3 << 20,
                evicted_keys: 7,
                evicted_bytes: 2 << 20,
                busy_rejections: 1,
                ttl_expired_keys: 3,
                retention_window: 4,
                retention_max_bytes: 8 << 20,
                retention_ttl_ms: 60_000,
                spilled_keys: 9,
                spilled_bytes: 3 << 20,
                spill_segments: 2,
                cold_hits: 6,
                spill_lost_keys: 1,
                replicated_writes: 11,
                read_failovers: 5,
                shard_reconnects: 2,
                degraded_ops: 1,
                model_swaps: 3,
                batches: 12,
                batched_requests: 40,
                engine: "redis".into(),
                fields: vec![
                    FieldPressure {
                        field: "u".into(),
                        resident_bytes: 1 << 19,
                        generations: 4,
                        evicted_keys: 5,
                        evicted_bytes: 1 << 20,
                        spilled_keys: 5,
                        spilled_bytes: 1 << 20,
                    },
                    FieldPressure {
                        field: "v".into(),
                        resident_bytes: 1 << 18,
                        generations: 2,
                        evicted_keys: 2,
                        evicted_bytes: 1 << 19,
                        spilled_keys: 0,
                        spilled_bytes: 0,
                    },
                ],
            }),
            Response::Batch(vec![
                Response::Ok,
                Response::Tensor(t),
                Response::NotFound,
                Response::Error("entry failed".into()),
            ]),
            Response::Models(vec![
                ModelEntry {
                    key: "encoder".into(),
                    live_version: 3,
                    n_versions: 3,
                    swaps: 2,
                    executions: 41,
                },
                ModelEntry {
                    key: "surrogate".into(),
                    live_version: 1,
                    n_versions: 1,
                    swaps: 0,
                    executions: 0,
                },
            ]),
            Response::ModelStats(vec![
                ModelDeviceStat {
                    device: Device::Cpu,
                    executions: 9,
                    eval_count: 9,
                    eval_mean_s: 0.0031,
                    eval_std_s: 0.0002,
                    queue_count: 0,
                    queue_mean_s: 0.0,
                    queue_std_s: 0.0,
                },
                ModelDeviceStat {
                    device: Device::Gpu(1),
                    executions: 32,
                    eval_count: 32,
                    eval_mean_s: 0.0008,
                    eval_std_s: 0.0001,
                    queue_count: 32,
                    queue_mean_s: 0.0003,
                    queue_std_s: 0.00005,
                },
            ]),
            Response::Version(4),
            Response::EpochTable { shard: 2, table: sample_table() },
            // The "no table installed" sentinel a standalone server replies
            // with: shard unset, epoch 0, no assignments.
            Response::EpochTable {
                shard: u16::MAX,
                table: SlotEpoch { epoch: 0, assignments: Vec::new() },
            },
        ]
    }

    #[test]
    fn response_roundtrips() {
        for c in all_response_variants() {
            assert_eq!(roundtrip_resp(&c), c);
        }
    }

    #[test]
    fn wire_size_is_exact_for_every_request_variant() {
        for c in all_request_variants() {
            let mut buf = Vec::new();
            c.encode(&mut buf);
            assert_eq!(c.wire_size(), buf.len() + 4, "wire_size mismatch for {c:?}");
        }
    }

    #[test]
    fn body_wire_size_is_exact_for_every_response_variant() {
        for c in all_response_variants() {
            let mut buf = Vec::new();
            c.encode(&mut buf);
            assert_eq!(c.body_wire_size(), buf.len(), "body size mismatch for {c:?}");
        }
    }

    #[test]
    fn nested_batches_are_rejected() {
        let mut buf = Vec::new();
        Request::Batch(vec![Request::Info]).encode(&mut buf);
        // Splice the encoded batch in as its own entry: opcode 12, count 1,
        // then the batch bytes again.
        let mut nested = vec![12u8, 1, 0, 0, 0];
        nested.extend_from_slice(&buf);
        assert!(Request::decode(&nested).is_err(), "nested request batch");

        let mut rbuf = Vec::new();
        Response::Batch(vec![Response::Ok]).encode(&mut rbuf);
        let mut rnested = vec![9u8, 1, 0, 0, 0];
        rnested.extend_from_slice(&rbuf);
        assert!(Response::decode(&rnested).is_err(), "nested response batch");
    }

    #[test]
    fn batch_tensors_share_one_frame_allocation() {
        // Every tensor in a batch reply decoded via decode_shared must alias
        // the single frame body — the batched-gather zero-copy property.
        let a = Tensor::from_f32(&[4], vec![1.0; 4]).unwrap();
        let b = Tensor::from_f32(&[8], vec![2.0; 8]).unwrap();
        let mut buf = Vec::new();
        Response::Batch(vec![
            Response::Tensor(a.clone()),
            Response::NotFound,
            Response::Tensor(b.clone()),
        ])
        .encode(&mut buf);
        let body = Bytes::from_vec(buf);
        match Response::decode_shared(&body).unwrap() {
            Response::Batch(entries) => {
                let (t0, t2) = match (&entries[0], &entries[2]) {
                    (Response::Tensor(x), Response::Tensor(y)) => (x, y),
                    other => panic!("unexpected entries {other:?}"),
                };
                assert!(t0.data.shares_allocation(&body));
                assert!(t2.data.shares_allocation(&body));
                assert_eq!(t0, &a);
                assert_eq!(t2, &b);
            }
            other => panic!("unexpected decode {other:?}"),
        }
    }

    #[test]
    fn frame_holds_payload_covers_batches() {
        let mut buf = Vec::new();
        Request::Batch(vec![Request::GetTensor { key: "k".into() }]).encode(&mut buf);
        assert!(Request::frame_holds_payload(&buf), "batches may carry payloads");
        let mut buf = Vec::new();
        Request::MGetTensors { keys: vec!["k".into()] }.encode(&mut buf);
        assert!(!Request::frame_holds_payload(&buf));
    }

    #[test]
    fn expect_conversions() {
        use crate::error::Error;
        assert!(Response::Ok.expect_ok().is_ok());
        assert!(matches!(
            Response::Error("boom".into()).expect_ok(),
            Err(Error::Remote(m)) if m == "boom"
        ));
        assert!(matches!(Response::Bool(true).expect_ok(), Err(Error::Protocol(_))));
        assert!(matches!(
            Response::NotFound.expect_tensor("k"),
            Err(Error::KeyNotFound(k)) if k == "k"
        ));
        assert!(Response::Ok.expect_deleted().unwrap());
        assert!(!Response::NotFound.expect_deleted().unwrap());
        assert!(!Response::Bool(false).expect_bool().unwrap());
        assert_eq!(Response::Meta("v".into()).expect_meta().unwrap(), Some("v".into()));
        assert_eq!(Response::NotFound.expect_meta().unwrap(), None);
        assert_eq!(Response::Keys(vec!["a".into()]).expect_keys().unwrap(), vec!["a"]);
        let info = DbInfo {
            keys: 1,
            bytes: 2,
            ops: 3,
            models: 0,
            engine: "redis".into(),
            ..Default::default()
        };
        assert_eq!(Response::Info(info.clone()).expect_info().unwrap(), info);
        assert!(Response::Batch(vec![Response::Ok]).expect_batch(1).is_ok());
        assert!(matches!(
            Response::Batch(vec![Response::Ok]).expect_batch(2),
            Err(Error::Protocol(_))
        ));
    }

    #[test]
    fn borrowed_put_tensor_encoding_is_byte_identical() {
        let t = Tensor::from_f32(&[3, 2], vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]).unwrap();
        let owned = Request::PutTensor { key: "k1".into(), tensor: t.clone() };
        let mut a = Vec::new();
        owned.encode(&mut a);
        let mut b = Vec::new();
        encode_put_tensor_into(&mut b, "k1", &t);
        assert_eq!(a, b);
        // The split header + payload path concatenates to the same body.
        let mut h = Vec::new();
        encode_put_tensor_header_into(&mut h, "k1", &t);
        h.extend_from_slice(&t.data);
        assert_eq!(a, h);
    }

    #[test]
    fn tensor_response_header_plus_payload_is_byte_identical() {
        let t = Tensor::from_f32(&[4], vec![9.0, 8.0, 7.0, 6.0]).unwrap();
        let mut whole = Vec::new();
        Response::Tensor(t.clone()).encode(&mut whole);
        let mut split = Vec::new();
        encode_tensor_response_header_into(&mut split, &t);
        split.extend_from_slice(&t.data);
        assert_eq!(whole, split);
    }

    #[test]
    fn shared_decode_aliases_frame_body() {
        let t = Tensor::from_f32(&[8], (0..8).map(|i| i as f32).collect()).unwrap();
        let r = Request::PutTensor { key: "k".into(), tensor: t.clone() };
        let mut buf = Vec::new();
        r.encode(&mut buf);
        assert!(Request::frame_holds_payload(&buf));
        let body = Bytes::from_vec(buf);
        match Request::decode_shared(&body).unwrap() {
            Request::PutTensor { tensor, .. } => {
                assert!(tensor.data.shares_allocation(&body), "payload must view the frame");
                assert_eq!(tensor, t, "view-backed decode is byte-identical");
            }
            other => panic!("unexpected decode {other:?}"),
        }
        assert!(!Request::frame_holds_payload(&{
            let mut b = Vec::new();
            Request::GetTensor { key: "k".into() }.encode(&mut b);
            b
        }));
    }

    #[test]
    fn shared_response_decode_aliases_frame_body() {
        let t = Tensor::from_f32(&[3], vec![1.0, 2.0, 3.0]).unwrap();
        let mut buf = Vec::new();
        Response::Tensor(t.clone()).encode(&mut buf);
        let body = Bytes::from_vec(buf);
        match Response::decode_shared(&body).unwrap() {
            Response::Tensor(got) => {
                assert!(got.data.shares_allocation(&body));
                assert_eq!(got, t);
            }
            other => panic!("unexpected decode {other:?}"),
        }
    }

    #[test]
    fn decode_rejects_garbage() {
        assert!(Request::decode(&[]).is_err());
        assert!(Request::decode(&[0xff]).is_err());
        // Truncated string length.
        assert!(Request::decode(&[1, 4, 0, 0]).is_err());
        // String body shorter than its declared length.
        assert!(Request::decode(&[1, 4, 0, 0, 0, b'a']).is_err());
    }

    #[test]
    fn prop_arbitrary_tensor_roundtrip() {
        check("proto tensor roundtrip", 200, |g: &mut Gen| {
            let ndim = g.usize_in(0..=4);
            let shape: Vec<usize> = (0..ndim).map(|_| g.usize_in(1..=8)).collect();
            let n: usize = shape.iter().product();
            let dt = *g.choose(&[DType::F32, DType::I32, DType::U8, DType::F64]);
            let data: Vec<u8> = (0..n * dt.size()).map(|_| g.u32() as u8).collect();
            let t = Tensor { dtype: dt, shape, data: data.into() };
            let r = Request::PutTensor { key: g.key(), tensor: t };
            assert_eq!(roundtrip_req(&r), r);
        });
    }

    #[test]
    fn prop_shared_decode_matches_owned_decode() {
        // The aliasing decode must be observationally identical to the old
        // owned decode for every payload it can carry.
        check("proto shared vs owned decode", 200, |g: &mut Gen| {
            let ndim = g.usize_in(0..=4);
            let shape: Vec<usize> = (0..ndim).map(|_| g.usize_in(1..=8)).collect();
            let n: usize = shape.iter().product();
            let dt = *g.choose(&[DType::F32, DType::I32, DType::U8, DType::F64]);
            let data: Vec<u8> = (0..n * dt.size()).map(|_| g.u32() as u8).collect();
            let t = Tensor { dtype: dt, shape, data: data.into() };
            let r = Request::PutTensor { key: g.key(), tensor: t };
            let mut buf = Vec::new();
            r.encode(&mut buf);
            let owned = Request::decode(&buf).expect("owned decode");
            let shared = Request::decode_shared(&Bytes::from_vec(buf)).expect("shared decode");
            assert_eq!(owned, shared);
            assert_eq!(owned, r);
        });
    }

    #[test]
    fn prop_decode_never_panics_on_fuzz() {
        // Malformed bytes must produce Err, never a panic/abort.
        check("proto fuzz decode", 500, |g: &mut Gen| {
            let bytes = g.vec(0..=64, |g| g.u32() as u8);
            let _ = Request::decode(&bytes);
            let _ = Response::decode(&bytes);
            let shared = Bytes::from_vec(bytes);
            let _ = Request::decode_shared(&shared);
            let _ = Response::decode_shared(&shared);
        });
    }

    #[test]
    fn prop_mutated_valid_frame_never_panics() {
        check("proto mutation decode", 300, |g: &mut Gen| {
            let t = Tensor::from_f32(&[2], vec![1.0, 2.0]).unwrap();
            let r = Request::RunModel {
                key: g.key(),
                version: g.u64(),
                in_keys: vec![g.key(), g.key()],
                out_keys: vec![g.key()],
                device: Device::Cpu,
            };
            let mut buf = Vec::new();
            r.encode(&mut buf);
            let r2 = Request::PutTensor { key: g.key(), tensor: t };
            r2.encode(&mut buf);
            // Flip a few bytes.
            for _ in 0..g.usize_in(1..=8) {
                let i = g.usize_in(0..=buf.len() - 1);
                buf[i] ^= g.u32() as u8;
            }
            let _ = Request::decode(&buf);
            let _ = Request::decode_shared(&Bytes::from_vec(buf));
        });
    }

    /// One random valid request per case, spanning every variant (including
    /// `Batch` nesting and the retention ops) — the corpus the corruption
    /// properties below mutate.
    fn arbitrary_request(g: &mut Gen) -> Request {
        let keys = |g: &mut Gen| -> Vec<String> { g.vec(0..=4, |g| g.key()) };
        match g.usize_in(0..=12) {
            0 => {
                let n = g.usize_in(1..=8);
                let data: Vec<f32> = (0..n).map(|_| g.normal_f32()).collect();
                Request::PutTensor { key: g.key(), tensor: Tensor::from_f32(&[n], data).unwrap() }
            }
            1 => Request::GetTensor { key: g.key() },
            2 => Request::DelKeys { keys: keys(g) },
            3 => Request::Retention { window: g.u64(), max_bytes: g.u64(), ttl_ms: g.u64() },
            4 => Request::MGetTensors { keys: keys(g) },
            5 => Request::PollKeys {
                keys: keys(g),
                timeout_ms: g.u64(),
                initial_us: g.u64(),
                cap_us: g.u64(),
            },
            6 => Request::PutMeta { key: g.key(), value: g.key() },
            7 => Request::ColdGet { key: g.key() },
            8 => Request::ColdList { prefix: g.key() },
            9 => Request::RunModel {
                key: g.key(),
                version: g.u64(),
                in_keys: keys(g),
                out_keys: keys(g),
                device: *g.choose(&[Device::Cpu, Device::Gpu(0), Device::Gpu(3)]),
            },
            10 => Request::ListModels,
            11 => Request::ModelStats,
            _ => Request::Batch(vec![
                Request::DelKeys { keys: keys(g) },
                Request::Retention { window: g.u64(), max_bytes: g.u64(), ttl_ms: g.u64() },
                Request::ColdGet { key: g.key() },
                Request::Exists { key: g.key() },
                Request::ListModels,
            ]),
        }
    }

    #[test]
    fn prop_truncated_encodings_always_error() {
        // Any strict prefix of a valid encoding must fail to decode: the
        // parser is prefix-deterministic and requires exact consumption, so
        // truncation can never be mistaken for a shorter valid message.
        check("proto truncation", 400, |g: &mut Gen| {
            let r = arbitrary_request(g);
            let mut buf = Vec::new();
            r.encode(&mut buf);
            let cut = g.usize_in(0..=buf.len() - 1);
            buf.truncate(cut);
            assert!(Request::decode(&buf).is_err(), "prefix of {r:?} decoded");
            assert!(Request::decode_shared(&Bytes::from_vec(buf)).is_err());
        });
    }

    #[test]
    fn prop_length_field_corruption_never_panics_or_overallocates() {
        // Smash a 4-byte window of a valid encoding with an extreme length
        // (the classic with_capacity(attacker_n) attack): decode must
        // return without panicking or aborting on allocation, and a decoded
        // value must re-encode to something that decodes identically.
        check("proto length corruption", 300, |g: &mut Gen| {
            let r = arbitrary_request(g);
            let mut buf = Vec::new();
            r.encode(&mut buf);
            let i = g.usize_in(0..=buf.len() - 1);
            let huge = if g.bool() { u32::MAX } else { u32::MAX / 2 };
            for (o, b) in huge.to_le_bytes().iter().enumerate() {
                if i + o < buf.len() {
                    buf[i + o] = *b;
                }
            }
            if let Ok(decoded) = Request::decode(&buf) {
                let mut re = Vec::new();
                decoded.encode(&mut re);
                assert_eq!(Request::decode(&re).unwrap(), decoded, "re-encode roundtrip");
            }
            let _ = Response::decode(&buf);
            let _ = Request::decode_shared(&Bytes::from_vec(buf));
        });
    }

    #[test]
    fn prop_bit_flips_on_new_messages_never_panic() {
        check("proto retention-op bitflips", 300, |g: &mut Gen| {
            let r = Request::Batch(vec![
                Request::DelKeys { keys: vec![g.key(), g.key()] },
                Request::Retention { window: g.u64(), max_bytes: g.u64(), ttl_ms: g.u64() },
            ]);
            let mut buf = Vec::new();
            r.encode(&mut buf);
            for _ in 0..g.usize_in(1..=6) {
                let i = g.usize_in(0..=buf.len() - 1);
                buf[i] ^= 1 << g.usize_in(0..=7);
            }
            let _ = Request::decode(&buf);
            let _ = Request::decode_shared(&Bytes::from_vec(buf));
        });
    }

    #[test]
    fn oversized_declared_counts_are_rejected_not_allocated() {
        // DelKeys with a declared key count over MAX_BATCH: the decoder
        // must refuse before reserving anything like that much memory.
        let mut buf = vec![15u8]; // req_op::DEL_KEYS
        buf.extend_from_slice(&((MAX_BATCH as u32) + 1).to_le_bytes());
        assert!(Request::decode(&buf).is_err());
        // Keys response with an absurd count and no body.
        let mut buf = vec![6u8]; // resp_op::KEYS
        buf.extend_from_slice(&u32::MAX.to_le_bytes());
        assert!(Response::decode(&buf).is_err());
        // Batch header declaring u32::MAX entries.
        let mut buf = vec![12u8]; // req_op::BATCH
        buf.extend_from_slice(&u32::MAX.to_le_bytes());
        assert!(Request::decode(&buf).is_err());
        // String length beyond MAX_FRAME.
        let mut buf = vec![2u8]; // req_op::GET_TENSOR
        buf.extend_from_slice(&u32::MAX.to_le_bytes());
        assert!(Request::decode(&buf).is_err());
        // Models response declaring an absurd registry size.
        let mut buf = vec![10u8]; // resp_op::MODELS
        buf.extend_from_slice(&u32::MAX.to_le_bytes());
        assert!(Response::decode(&buf).is_err());
        // ModelStats response declaring more device rows than can exist.
        let mut buf = vec![11u8]; // resp_op::MODEL_STATS
        buf.extend_from_slice(&1024u32.to_le_bytes());
        assert!(Response::decode(&buf).is_err());
    }

    #[test]
    fn model_ops_broadcast_and_truncate_strictly() {
        // Model ops never route to one shard: the registry lives in every
        // shard's runtime, so listings merge and publishes broadcast.
        for r in [
            Request::ListModels,
            Request::ModelStats,
            Request::PutModel { key: "m".into(), hlo_text: "situ-native v1".into() },
            Request::RunModel {
                key: "m".into(),
                version: 2,
                in_keys: vec!["x".into()],
                out_keys: vec!["y".into()],
                device: Device::Gpu(0),
            },
        ] {
            assert!(r.routing_key().is_none(), "{r:?} must not route");
            assert_eq!(roundtrip_req(&r), r);
        }
        // Every strict prefix of the serving frames must fail to decode.
        for resp in all_response_variants() {
            if !matches!(
                resp,
                Response::Models(_) | Response::ModelStats(_) | Response::Version(_)
            ) {
                continue;
            }
            let mut buf = Vec::new();
            resp.encode(&mut buf);
            for cut in 0..buf.len() {
                assert!(
                    Response::decode(&buf[..cut]).is_err(),
                    "prefix {cut} of {resp:?} decoded"
                );
            }
        }
        let versioned = Request::RunModel {
            key: "m".into(),
            version: u64::MAX,
            in_keys: vec!["a".into()],
            out_keys: vec!["b".into()],
            device: Device::Cpu,
        };
        let mut buf = Vec::new();
        versioned.encode(&mut buf);
        for cut in 0..buf.len() {
            assert!(Request::decode(&buf[..cut]).is_err());
        }
        assert_eq!(roundtrip_req(&versioned), versioned);
    }

    #[test]
    fn serving_expect_conversions() {
        use crate::error::Error;
        assert_eq!(Response::Version(9).expect_version().unwrap(), 9);
        assert!(matches!(Response::Ok.expect_version(), Err(Error::Protocol(_))));
        let ms = vec![ModelEntry { key: "m".into(), live_version: 1, ..Default::default() }];
        assert_eq!(Response::Models(ms.clone()).expect_models().unwrap(), ms);
        assert!(matches!(
            Response::Error("busy: store full".into()).expect_models(),
            Err(Error::Busy(_))
        ));
        assert!(Response::ModelStats(Vec::new()).expect_model_stats().unwrap().is_empty());
        assert!(matches!(Response::NotFound.expect_model_stats(), Err(Error::Protocol(_))));
    }

    #[test]
    fn retention_ops_inside_batches_roundtrip() {
        let r = Request::Batch(vec![
            Request::DelKeys { keys: vec!["a".into(), "b".into()] },
            Request::Retention { window: 3, max_bytes: 1 << 20, ttl_ms: 5_000 },
            Request::Info,
        ]);
        assert_eq!(roundtrip_req(&r), r);
        assert_eq!(r.body_wire_size(), {
            let mut b = Vec::new();
            r.encode(&mut b);
            b.len()
        });
        assert!(r.routing_key().is_none(), "retention ops are whole-database");
    }

    #[test]
    fn cluster_ops_are_driver_directed_and_strict() {
        use crate::error::Error;
        // The elastic-cluster ops are always aimed at a specific shard via
        // on_shard, never slot-routed.
        for r in [
            Request::ClusterEpoch { install: None },
            Request::ClusterEpoch { install: Some((0, 1, sample_table())) },
            Request::ExportSlots { lo: 0, hi: 100 },
            Request::ColdPut {
                key: "k".into(),
                tensor: Tensor::from_f32(&[1], vec![1.0]).unwrap(),
            },
        ] {
            assert!(r.routing_key().is_none(), "{r:?} must not slot-route");
            assert_eq!(roundtrip_req(&r), r);
        }
        // ColdPut carries a payload the spill writer retains past
        // execution, so its frame must be handed over wholesale.
        let mut buf = Vec::new();
        Request::ColdPut { key: "k".into(), tensor: Tensor::from_f32(&[1], vec![2.0]).unwrap() }
            .encode(&mut buf);
        assert!(Request::frame_holds_payload(&buf));
        // Installing an empty table is a protocol error (empty means "no
        // table" and only appears in replies); an inverted export range too.
        let mut buf = Vec::new();
        Request::ClusterEpoch {
            install: Some((0, 1, SlotEpoch { epoch: 3, assignments: Vec::new() })),
        }
        .encode(&mut buf);
        assert!(Request::decode(&buf).is_err(), "empty install must be rejected");
        let mut buf = Vec::new();
        Request::ExportSlots { lo: 9, hi: 3 }.encode(&mut buf);
        assert!(Request::decode(&buf).is_err(), "inverted slot range must be rejected");
        // The "moved: <epoch>" reply string maps back to Error::Moved, the
        // signal the cluster client retries on after a table refetch.
        assert!(matches!(
            Response::Error("moved: 42".into()).expect_ok(),
            Err(Error::Moved(42))
        ));
        let (shard, table) = Response::EpochTable { shard: 1, table: sample_table() }
            .expect_epoch_table()
            .unwrap();
        assert_eq!(shard, 1);
        assert_eq!(table, sample_table());
        assert!(Response::Ok.expect_epoch_table().is_err());
    }

    #[test]
    fn cold_ops_route_like_their_hot_counterparts() {
        // ColdGet routes on its key — the shard that evicted (and thus
        // spilled) a key is the shard the key hashes to, so cold reads can
        // be pipelined on a cluster.  ColdList spans the whole database,
        // like ListKeys.
        let get = Request::ColdGet { key: "f_rank0_step3".into() };
        assert_eq!(get.routing_key(), Some("f_rank0_step3"));
        let list = Request::ColdList { prefix: "f_".into() };
        assert!(list.routing_key().is_none());
        assert_eq!(roundtrip_req(&get), get);
        assert_eq!(roundtrip_req(&list), list);
    }
}
