//! Parser for `artifacts/manifest.json`, the contract between the AOT
//! compile path (python) and the rust runtime: artifact signatures, the
//! canonical parameter ordering, and model/mesh hyperparameters.

use std::collections::BTreeMap;
use std::path::Path;

use crate::error::{Error, Result};
use crate::tensor::DType;
use crate::util::json::Json;

/// One input/output slot of an artifact.
#[derive(Debug, Clone, PartialEq)]
pub struct TensorSig {
    pub name: String,
    pub dtype: DType,
    pub shape: Vec<usize>,
}

impl TensorSig {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn nbytes(&self) -> usize {
        self.numel() * self.dtype.size()
    }

    fn parse(j: &Json) -> Result<TensorSig> {
        Ok(TensorSig {
            name: j
                .req("name")?
                .as_str()
                .ok_or_else(|| Error::Parse("sig name".into()))?
                .to_string(),
            dtype: DType::from_manifest(
                j.req("dtype")?.as_str().ok_or_else(|| Error::Parse("sig dtype".into()))?,
            )?,
            shape: j.req("shape")?.usize_array()?,
        })
    }
}

/// One lowered HLO artifact.
#[derive(Debug, Clone)]
pub struct ArtifactSig {
    pub file: String,
    pub inputs: Vec<TensorSig>,
    pub outputs: Vec<TensorSig>,
}

/// One row of the parameter table (offsets into `params_init.bin`).
#[derive(Debug, Clone)]
pub struct ParamRow {
    pub name: String,
    pub shape: Vec<usize>,
    pub offset: usize,
    pub len: usize,
}

/// Model hyperparameters recorded by `aot.py`.
#[derive(Debug, Clone)]
pub struct ModelInfo {
    pub channels: usize,
    pub n_points: usize,
    pub latent: usize,
    pub batch: usize,
    pub lr: f64,
    pub n_param_tensors: usize,
    pub n_params_total: usize,
    pub compression_factor: f64,
}

/// The parsed manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub model: ModelInfo,
    pub param_order: Vec<String>,
    pub enc_param_order: Vec<String>,
    pub dec_param_order: Vec<String>,
    pub param_table: Vec<ParamRow>,
    pub artifacts: BTreeMap<String, ArtifactSig>,
    pub mesh_levels: Vec<Vec<usize>>,
}

impl Manifest {
    pub fn load(path: &Path) -> Result<Manifest> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| Error::Parse(format!("read {}: {e}", path.display())))?;
        Manifest::parse(&text)
    }

    /// Convenience: load from an artifacts directory.
    pub fn load_dir(dir: &Path) -> Result<Manifest> {
        Manifest::load(&dir.join("manifest.json"))
    }

    pub fn parse(text: &str) -> Result<Manifest> {
        let j = Json::parse(text)?;
        let m = j.req("model")?;
        let model = ModelInfo {
            channels: m.req("channels")?.as_usize().unwrap_or(0),
            n_points: m.req("n_points")?.as_usize().unwrap_or(0),
            latent: m.req("latent")?.as_usize().unwrap_or(0),
            batch: m.req("batch")?.as_usize().unwrap_or(0),
            lr: m.req("lr")?.as_f64().unwrap_or(0.0),
            n_param_tensors: m.req("n_param_tensors")?.as_usize().unwrap_or(0),
            n_params_total: m.req("n_params_total")?.as_usize().unwrap_or(0),
            compression_factor: m.req("compression_factor")?.as_f64().unwrap_or(0.0),
        };
        let str_arr = |key: &str| -> Result<Vec<String>> {
            j.req(key)?
                .as_arr()
                .ok_or_else(|| Error::Parse(format!("{key} not an array")))?
                .iter()
                .map(|v| {
                    v.as_str()
                        .map(str::to_string)
                        .ok_or_else(|| Error::Parse(format!("{key}: non-string")))
                })
                .collect()
        };
        let param_order = str_arr("param_order")?;
        let enc_param_order = str_arr("enc_param_order")?;
        let dec_param_order = str_arr("dec_param_order")?;

        let mut param_table = Vec::new();
        for row in j
            .req("param_table")?
            .as_arr()
            .ok_or_else(|| Error::Parse("param_table".into()))?
        {
            param_table.push(ParamRow {
                name: row.req("name")?.as_str().unwrap_or("").to_string(),
                shape: row.req("shape")?.usize_array()?,
                offset: row.req("offset")?.as_usize().unwrap_or(0),
                len: row.req("len")?.as_usize().unwrap_or(0),
            });
        }

        let mut artifacts = BTreeMap::new();
        for (name, art) in j
            .req("artifacts")?
            .as_obj()
            .ok_or_else(|| Error::Parse("artifacts".into()))?
        {
            let inputs = art
                .req("inputs")?
                .as_arr()
                .ok_or_else(|| Error::Parse("inputs".into()))?
                .iter()
                .map(TensorSig::parse)
                .collect::<Result<Vec<_>>>()?;
            let outputs = art
                .req("outputs")?
                .as_arr()
                .ok_or_else(|| Error::Parse("outputs".into()))?
                .iter()
                .map(TensorSig::parse)
                .collect::<Result<Vec<_>>>()?;
            artifacts.insert(
                name.clone(),
                ArtifactSig {
                    file: art.req("file")?.as_str().unwrap_or("").to_string(),
                    inputs,
                    outputs,
                },
            );
        }

        let mesh_levels = j
            .req("mesh")?
            .req("levels")?
            .as_arr()
            .ok_or_else(|| Error::Parse("mesh.levels".into()))?
            .iter()
            .map(|l| l.usize_array())
            .collect::<Result<Vec<_>>>()?;

        let out = Manifest {
            model,
            param_order,
            enc_param_order,
            dec_param_order,
            param_table,
            artifacts,
            mesh_levels,
        };
        out.validate()?;
        Ok(out)
    }

    /// Structural invariants the rust side depends on.
    pub fn validate(&self) -> Result<()> {
        if self.param_order.len() != self.model.n_param_tensors {
            return Err(Error::Parse("param_order length mismatch".into()));
        }
        if self.param_table.len() != self.param_order.len() {
            return Err(Error::Parse("param_table length mismatch".into()));
        }
        let mut off = 0usize;
        for (row, name) in self.param_table.iter().zip(&self.param_order) {
            if &row.name != name {
                return Err(Error::Parse(format!(
                    "param_table order mismatch: {} vs {}",
                    row.name, name
                )));
            }
            if row.offset != off {
                return Err(Error::Parse(format!("param {} offset gap", row.name)));
            }
            let numel: usize = row.shape.iter().product();
            if numel != row.len {
                return Err(Error::Parse(format!("param {} len/shape mismatch", row.name)));
            }
            off += row.len;
        }
        if off != self.model.n_params_total {
            return Err(Error::Parse("n_params_total mismatch".into()));
        }
        for key in ["train_step", "eval_step", "encoder", "decoder", "autoencoder"] {
            if !self.artifacts.contains_key(key) {
                return Err(Error::Parse(format!("manifest missing artifact '{key}'")));
            }
        }
        Ok(())
    }

    pub fn artifact(&self, name: &str) -> Result<&ArtifactSig> {
        self.artifacts
            .get(name)
            .ok_or_else(|| Error::ModelNotFound(name.to_string()))
    }

    /// Total bytes of one training sample `[channels, n_points]` f32.
    pub fn sample_nbytes(&self) -> usize {
        self.model.channels * self.model.n_points * 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const MINI: &str = r#"{
      "model": {"channels": 2, "n_points": 4, "latent": 3, "batch": 1,
                 "lr": 0.001, "adam": {"b1":0.9,"b2":0.999,"eps":1e-8},
                 "n_param_tensors": 2, "n_params_total": 10,
                 "compression_factor": 2.67},
      "mesh": {"levels": [[2,2,1]], "domain": [1,1,1], "beta": 2.0,
                "k_enc": 2, "k_dec": 2},
      "param_order": ["a", "b"],
      "enc_param_order": ["a"],
      "dec_param_order": ["b"],
      "param_table": [
        {"name": "a", "shape": [2,3], "offset": 0, "len": 6},
        {"name": "b", "shape": [4], "offset": 6, "len": 4}
      ],
      "artifacts": {
        "train_step": {"file": "t.hlo.txt", "inputs": [{"name":"a","dtype":"float32","shape":[2,3]}], "outputs": [{"name":"loss","dtype":"float32","shape":[]}]},
        "eval_step": {"file": "e.hlo.txt", "inputs": [], "outputs": []},
        "encoder": {"file": "en.hlo.txt", "inputs": [], "outputs": []},
        "decoder": {"file": "de.hlo.txt", "inputs": [], "outputs": []},
        "autoencoder": {"file": "ae.hlo.txt", "inputs": [], "outputs": []}
      }
    }"#;

    #[test]
    fn parses_minimal_manifest() {
        let m = Manifest::parse(MINI).unwrap();
        assert_eq!(m.model.latent, 3);
        assert_eq!(m.param_order, vec!["a", "b"]);
        assert_eq!(m.artifact("train_step").unwrap().inputs[0].shape, vec![2, 3]);
        assert_eq!(m.sample_nbytes(), 2 * 4 * 4);
        assert_eq!(m.artifact("train_step").unwrap().outputs[0].nbytes(), 4);
    }

    #[test]
    fn rejects_offset_gap() {
        let bad = MINI.replace("\"offset\": 6", "\"offset\": 7");
        assert!(Manifest::parse(&bad).is_err());
    }

    #[test]
    fn rejects_order_mismatch() {
        let bad = MINI.replace("[\"a\", \"b\"]", "[\"b\", \"a\"]");
        assert!(Manifest::parse(&bad).is_err());
    }

    #[test]
    fn rejects_missing_artifact() {
        let bad = MINI.replace("\"train_step\"", "\"train_stepX\"");
        assert!(Manifest::parse(&bad).is_err());
    }
}
