//! PJRT runtime: loads the AOT artifacts (`artifacts/*.hlo.txt`) and executes
//! them on the request path.
//!
//! The `xla` crate's `PjRtClient` is `Rc`-based (`!Send`), so the runtime
//! owns one dedicated **executor thread** holding the client and every
//! compiled executable; callers talk to it through a channel.  This matches
//! the production PJRT threading model (client construction pinned to one
//! thread, executions serialized per device) and keeps the rest of the crate
//! free to be multi-threaded.
//!
//! Interchange format is HLO *text* — see `python/compile/aot.py` for why
//! serialized protos are rejected by xla_extension 0.5.1.

pub mod manifest;

pub use manifest::{ArtifactSig, Manifest, TensorSig};

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::mpsc;
use std::sync::Arc;

use crate::error::{Error, Result};
use crate::tensor::{DType, Tensor};

enum Job {
    /// Compile HLO text from a file and cache under a name.
    LoadFile { name: String, path: PathBuf, reply: mpsc::Sender<Result<()>> },
    /// Compile HLO text provided inline (model upload over the wire).
    LoadText { name: String, text: String, reply: mpsc::Sender<Result<()>> },
    Execute { name: String, inputs: Vec<Tensor>, reply: mpsc::Sender<Result<Vec<Tensor>>> },
    Unload { name: String, reply: mpsc::Sender<Result<()>> },
    Loaded { reply: mpsc::Sender<Vec<String>> },
}

/// Handle to the executor thread.  Cheap to clone; all clones share the
/// same compiled-executable cache.
#[derive(Clone)]
pub struct Executor {
    tx: mpsc::Sender<Job>,
    _shared: Arc<()>,
}

impl Executor {
    /// Spawn the executor thread with a CPU PJRT client.
    pub fn new() -> Result<Executor> {
        let (tx, rx) = mpsc::channel::<Job>();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        std::thread::Builder::new()
            .name("pjrt-executor".into())
            .spawn(move || worker(rx, ready_tx))
            .map_err(Error::Io)?;
        ready_rx
            .recv()
            .map_err(|_| Error::Xla("executor thread died during init".into()))??;
        Ok(Executor { tx, _shared: Arc::new(()) })
    }

    fn rpc<T>(&self, mk: impl FnOnce(mpsc::Sender<T>) -> Job) -> Result<T> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(mk(reply))
            .map_err(|_| Error::Xla("executor thread gone".into()))?;
        rx.recv().map_err(|_| Error::Xla("executor thread gone".into()))
    }

    /// Compile `path` (HLO text) and cache it under `name`.
    pub fn load_artifact(&self, name: &str, path: &Path) -> Result<()> {
        self.rpc(|reply| Job::LoadFile { name: name.into(), path: path.into(), reply })?
    }

    /// Compile inline HLO text (the `put_model` wire path).
    pub fn load_hlo_text(&self, name: &str, text: &str) -> Result<()> {
        self.rpc(|reply| Job::LoadText { name: name.into(), text: text.into(), reply })?
    }

    /// Execute a loaded artifact.  Inputs must match the artifact signature
    /// (the manifest is the source of truth; the DB server validates).
    pub fn execute(&self, name: &str, inputs: Vec<Tensor>) -> Result<Vec<Tensor>> {
        self.rpc(|reply| Job::Execute { name: name.into(), inputs, reply })?
    }

    pub fn unload(&self, name: &str) -> Result<()> {
        self.rpc(|reply| Job::Unload { name: name.into(), reply })?
    }

    pub fn loaded(&self) -> Result<Vec<String>> {
        self.rpc(|reply| Job::Loaded { reply })
    }

    /// Load every artifact listed in a manifest from its directory.
    pub fn load_manifest(&self, m: &Manifest, dir: &Path) -> Result<()> {
        for (name, art) in &m.artifacts {
            self.load_artifact(name, &dir.join(&art.file))?;
        }
        Ok(())
    }
}

fn worker(rx: mpsc::Receiver<Job>, ready: mpsc::Sender<Result<()>>) {
    let client = match xla::PjRtClient::cpu() {
        Ok(c) => {
            let _ = ready.send(Ok(()));
            c
        }
        Err(e) => {
            let _ = ready.send(Err(Error::Xla(e.to_string())));
            return;
        }
    };
    let mut cache: HashMap<String, xla::PjRtLoadedExecutable> = HashMap::new();
    while let Ok(job) = rx.recv() {
        match job {
            Job::LoadFile { name, path, reply } => {
                let _ = reply.send(compile_file(&client, &path).map(|exe| {
                    cache.insert(name, exe);
                }));
            }
            Job::LoadText { name, text, reply } => {
                let _ = reply.send(compile_text(&client, &text).map(|exe| {
                    cache.insert(name, exe);
                }));
            }
            Job::Execute { name, inputs, reply } => {
                let out = match cache.get(&name) {
                    None => Err(Error::ModelNotFound(name.clone())),
                    Some(exe) => execute_one(exe, &inputs),
                };
                let _ = reply.send(out);
            }
            Job::Unload { name, reply } => {
                cache.remove(&name);
                let _ = reply.send(Ok(()));
            }
            Job::Loaded { reply } => {
                let mut names: Vec<String> = cache.keys().cloned().collect();
                names.sort();
                let _ = reply.send(names);
            }
        }
    }
}

fn compile_file(client: &xla::PjRtClient, path: &Path) -> Result<xla::PjRtLoadedExecutable> {
    let proto = xla::HloModuleProto::from_text_file(path)
        .map_err(|e| Error::Xla(format!("parse {}: {e}", path.display())))?;
    let comp = xla::XlaComputation::from_proto(&proto);
    client
        .compile(&comp)
        .map_err(|e| Error::Xla(format!("compile {}: {e}", path.display())))
}

fn compile_text(client: &xla::PjRtClient, text: &str) -> Result<xla::PjRtLoadedExecutable> {
    // The crate only exposes a file-based text parser; stage through a
    // uniquely-named temp file.
    use std::sync::atomic::{AtomicU64, Ordering};
    static SEQ: AtomicU64 = AtomicU64::new(0);
    let path = std::env::temp_dir().join(format!(
        "situ-hlo-{}-{}.txt",
        std::process::id(),
        SEQ.fetch_add(1, Ordering::Relaxed)
    ));
    std::fs::write(&path, text)?;
    let out = compile_file(client, &path);
    let _ = std::fs::remove_file(&path);
    out
}

fn execute_one(exe: &xla::PjRtLoadedExecutable, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
    let literals: Vec<xla::Literal> = inputs.iter().map(tensor_to_literal).collect::<Result<_>>()?;
    let result = exe
        .execute::<xla::Literal>(&literals)
        .map_err(|e| Error::Xla(format!("execute: {e}")))?;
    let first = result
        .first()
        .and_then(|r| r.first())
        .ok_or_else(|| Error::Xla("empty execution result".into()))?;
    let lit = first
        .to_literal_sync()
        .map_err(|e| Error::Xla(format!("to_literal: {e}")))?;
    // aot.py lowers with return_tuple=True: the root is always a tuple.
    let parts = lit
        .to_tuple()
        .map_err(|e| Error::Xla(format!("to_tuple: {e}")))?;
    parts.iter().map(literal_to_tensor).collect()
}

fn dtype_to_element(dt: DType) -> xla::ElementType {
    match dt {
        DType::F32 => xla::ElementType::F32,
        DType::F64 => xla::ElementType::F64,
        DType::I32 => xla::ElementType::S32,
        DType::U8 => xla::ElementType::U8,
    }
}

fn element_to_dtype(e: xla::ElementType) -> Result<DType> {
    Ok(match e {
        xla::ElementType::F32 => DType::F32,
        xla::ElementType::F64 => DType::F64,
        xla::ElementType::S32 => DType::I32,
        xla::ElementType::U8 => DType::U8,
        other => return Err(Error::Xla(format!("unsupported output element type {other:?}"))),
    })
}

/// Tensor -> PJRT literal (zero conversion: raw LE bytes move straight in).
pub fn tensor_to_literal(t: &Tensor) -> Result<xla::Literal> {
    t.validate()?;
    xla::Literal::create_from_shape_and_untyped_data(dtype_to_element(t.dtype), &t.shape, &t.data)
        .map_err(|e| Error::Xla(format!("literal: {e}")))
}

/// PJRT literal -> Tensor.
pub fn literal_to_tensor(lit: &xla::Literal) -> Result<Tensor> {
    let shape = lit
        .array_shape()
        .map_err(|e| Error::Xla(format!("array_shape: {e}")))?;
    let dtype = element_to_dtype(shape.ty())?;
    let dims: Vec<usize> = shape.dims().iter().map(|d| *d as usize).collect();
    let n: usize = dims.iter().product();
    let mut data = vec![0u8; n * dtype.size()];
    match dtype {
        DType::F32 => {
            let v = lit.to_vec::<f32>().map_err(|e| Error::Xla(e.to_string()))?;
            for (c, x) in data.chunks_exact_mut(4).zip(&v) {
                c.copy_from_slice(&x.to_le_bytes());
            }
        }
        DType::F64 => {
            let v = lit.to_vec::<f64>().map_err(|e| Error::Xla(e.to_string()))?;
            for (c, x) in data.chunks_exact_mut(8).zip(&v) {
                c.copy_from_slice(&x.to_le_bytes());
            }
        }
        DType::I32 => {
            let v = lit.to_vec::<i32>().map_err(|e| Error::Xla(e.to_string()))?;
            for (c, x) in data.chunks_exact_mut(4).zip(&v) {
                c.copy_from_slice(&x.to_le_bytes());
            }
        }
        DType::U8 => {
            let v = lit.to_vec::<u8>().map_err(|e| Error::Xla(e.to_string()))?;
            data.copy_from_slice(&v);
        }
    }
    Ok(Tensor { dtype, shape: dims, data: data.into() })
}
