//! Uniform collocated grid with periodic x/z and wall-bounded y.

/// Grid geometry + flat scalar-field helpers.  Storage order is x-fastest
/// (`idx = (k*ny + j)*nx + i`).
#[derive(Debug, Clone)]
pub struct Grid {
    pub nx: usize,
    pub ny: usize,
    pub nz: usize,
    pub lx: f64,
    pub ly: f64,
    pub lz: f64,
}

impl Grid {
    pub fn new(nx: usize, ny: usize, nz: usize, lx: f64, ly: f64, lz: f64) -> Grid {
        assert!(nx >= 2 && ny >= 3 && nz >= 2, "grid too small");
        Grid { nx, ny, nz, lx, ly, lz }
    }

    /// Channel default used by the in-situ training example: matches the
    /// python mesh sampling box (mesh.py: LX=4, LY=2, LZ=2).
    pub fn channel(nx: usize, ny: usize, nz: usize) -> Grid {
        Grid::new(nx, ny, nz, 4.0, 2.0, 2.0)
    }

    pub fn n(&self) -> usize {
        self.nx * self.ny * self.nz
    }

    pub fn dx(&self) -> f64 {
        self.lx / self.nx as f64
    }

    /// Wall-normal spacing (cell-centered, first center at dy/2).
    pub fn dy(&self) -> f64 {
        self.ly / self.ny as f64
    }

    pub fn dz(&self) -> f64 {
        self.lz / self.nz as f64
    }

    #[inline]
    pub fn idx(&self, i: usize, j: usize, k: usize) -> usize {
        (k * self.ny + j) * self.nx + i
    }

    /// Cell-center coordinates.
    pub fn x(&self, i: usize) -> f64 {
        (i as f64 + 0.5) * self.dx()
    }

    pub fn y(&self, j: usize) -> f64 {
        (j as f64 + 0.5) * self.dy()
    }

    pub fn z(&self, k: usize) -> f64 {
        (k as f64 + 0.5) * self.dz()
    }

    /// Periodic neighbor in x.
    #[inline]
    pub fn ip(&self, i: usize) -> usize {
        if i + 1 == self.nx {
            0
        } else {
            i + 1
        }
    }

    #[inline]
    pub fn im(&self, i: usize) -> usize {
        if i == 0 {
            self.nx - 1
        } else {
            i - 1
        }
    }

    #[inline]
    pub fn kp(&self, k: usize) -> usize {
        if k + 1 == self.nz {
            0
        } else {
            k + 1
        }
    }

    #[inline]
    pub fn km(&self, k: usize) -> usize {
        if k == 0 {
            self.nz - 1
        } else {
            k - 1
        }
    }

    pub fn zeros(&self) -> Vec<f64> {
        vec![0.0; self.n()]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn indexing_is_bijective() {
        let g = Grid::channel(6, 4, 5);
        let mut seen = vec![false; g.n()];
        for k in 0..g.nz {
            for j in 0..g.ny {
                for i in 0..g.nx {
                    let id = g.idx(i, j, k);
                    assert!(!seen[id]);
                    seen[id] = true;
                }
            }
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn periodic_wrapping() {
        let g = Grid::channel(4, 4, 4);
        assert_eq!(g.ip(3), 0);
        assert_eq!(g.im(0), 3);
        assert_eq!(g.kp(3), 0);
        assert_eq!(g.km(0), 3);
    }

    #[test]
    fn coordinates_span_domain() {
        let g = Grid::channel(8, 8, 8);
        assert!(g.x(0) > 0.0 && g.x(7) < g.lx);
        assert!((g.y(7) + g.dy() / 2.0 - g.ly).abs() < 1e-12);
    }
}
