//! Hybrid ML/numeric pressure solver — the serving side of the in-situ
//! loop.
//!
//! The expensive part of every projection step is the pressure Poisson
//! solve.  The hybrid solver replaces it with an inference call against the
//! database's live `pressure_surrogate` model, then *validates* the
//! prediction by measuring the relative L2 residual `‖∇²p̂ − b‖ / ‖b‖` of
//! the returned field against the step's actual right-hand side.  Within
//! tolerance the prediction is accepted as the step's pressure; otherwise
//! the solver falls back to the numeric CG solve, warm-started from the
//! prediction so even a mediocre surrogate still pays for itself in
//! iterations saved.  Every outcome is counted, so a run reports exactly
//! how often the model was trusted.
//!
//! The trainer closes the loop by publishing improved checkpoints into the
//! same registry key mid-run ([`crate::ai::Registry`] hot-swaps the live
//! pointer); the solver picks up version N+1 on its next step without any
//! coordination, and in-flight steps on version N complete untouched.

use crate::client::{DataStore, Pipeline};
use crate::error::{Error, Result};
use crate::proto::Device;
use crate::sim::cfd::grid::Grid;
use crate::sim::cfd::poisson;
use crate::sim::cfd::solver::ChannelFlow;
use crate::telemetry::StatAccum;
use crate::tensor::Tensor;

/// Knobs of the hybrid pressure solve.
#[derive(Debug, Clone)]
pub struct HybridConfig {
    /// Registry key the surrogate is served under; the solver always runs
    /// the *live* version (wire version 0).
    pub model_key: String,
    /// Rank tag for this solver's scratch keys (parallel solvers must not
    /// share staging tensors).
    pub rank: usize,
    /// Acceptance threshold on the relative L2 residual of a prediction.
    pub accept_tol: f64,
    /// Numeric fallback tolerance.
    pub cg_tol: f64,
    /// Numeric fallback iteration cap.
    pub cg_max_iter: usize,
    /// Device the inference call is pinned to.
    pub device: Device,
}

impl Default for HybridConfig {
    fn default() -> Self {
        HybridConfig {
            model_key: "pressure_surrogate".into(),
            rank: 0,
            accept_tol: 1e-4,
            cg_tol: 1e-6,
            cg_max_iter: 600,
            device: Device::Gpu(0),
        }
    }
}

/// Per-run accounting of how the hybrid solve resolved each step.
#[derive(Debug, Default, Clone)]
pub struct HybridStats {
    /// Steps advanced through the hybrid path.
    pub steps: u64,
    /// Predictions that passed the residual check and became the step's
    /// pressure with no numeric work.
    pub accepted: u64,
    /// Steps that fell back to the numeric solve (failed validation or a
    /// failed inference call).
    pub fallbacks: u64,
    /// Inference calls that errored outright (e.g. no checkpoint published
    /// yet) — always also counted as fallbacks.
    pub surrogate_errors: u64,
    /// Relative residuals of the predictions that came back (accepted or
    /// not) — the run's surrogate-quality curve.
    pub residuals: StatAccum,
}

impl HybridStats {
    /// Fraction of steps served entirely by the surrogate.
    pub fn acceptance_rate(&self) -> f64 {
        if self.steps == 0 {
            0.0
        } else {
            self.accepted as f64 / self.steps as f64
        }
    }
}

/// Render the native-interpreter surrogate text for a pressure model on
/// `grid`.  Publishing checkpoints with a rising iteration budget mimics a
/// model improving over training epochs — each checkpoint is a strictly
/// better approximation of the true solve.
pub fn poisson_model_text(grid: &Grid, tol: f64, max_iter: usize) -> String {
    format!(
        "situ-native v1\npoisson {} {} {} {} {}\n",
        grid.nx, grid.ny, grid.nz, tol, max_iter
    )
}

/// A [`ChannelFlow`] stepped with surrogate-first pressure solves.
///
/// Generic over [`DataStore`], so the same solver runs against a co-located
/// instance or a cluster unchanged.
pub struct HybridSolver<C: DataStore> {
    pub cfg: HybridConfig,
    pub client: C,
    pub stats: HybridStats,
    /// Most recent inference failure, kept for the run report.
    pub last_error: Option<String>,
}

impl<C: DataStore> HybridSolver<C> {
    pub fn new(client: C, cfg: HybridConfig) -> HybridSolver<C> {
        HybridSolver { cfg, client, stats: HybridStats::default(), last_error: None }
    }

    /// Advance `flow` one step.  The pressure comes from the live surrogate
    /// when its validated residual is within `accept_tol`, otherwise from
    /// the CG fallback warm-started with whatever the surrogate produced.
    /// Returns the numeric iteration count (0 for an accepted prediction).
    pub fn step(&mut self, flow: &mut ChannelFlow) -> usize {
        let cfg = &self.cfg;
        let stats = &mut self.stats;
        let last_error = &mut self.last_error;
        let client = &mut self.client;
        let iters = flow.step_with(|g, rhs, p| {
            match Self::surrogate(client, cfg, g, rhs, p) {
                Ok(residual) => {
                    stats.residuals.add(residual);
                    if residual <= cfg.accept_tol {
                        stats.accepted += 1;
                        (0, residual)
                    } else {
                        // `p` already holds the prediction: the numeric
                        // solve below is warm-started by it.
                        stats.fallbacks += 1;
                        poisson::solve_cg(g, rhs, p, cfg.cg_tol, cfg.cg_max_iter)
                    }
                }
                Err(e) => {
                    stats.surrogate_errors += 1;
                    stats.fallbacks += 1;
                    *last_error = Some(e.to_string());
                    poisson::solve_cg(g, rhs, p, cfg.cg_tol, cfg.cg_max_iter)
                }
            }
        });
        stats.steps += 1;
        iters
    }

    /// One inference round trip: stage `rhs` and the previous pressure (the
    /// surrogate's warm-start input) in a single pipelined frame, run the
    /// live model, read the prediction back, and score it.  On success `p`
    /// holds the prediction and the relative residual is returned.
    fn surrogate(
        client: &mut C,
        cfg: &HybridConfig,
        g: &Grid,
        rhs: &[f64],
        p: &mut [f64],
    ) -> Result<f64> {
        let shape = [g.nx, g.ny, g.nz];
        let rhs_t = Tensor::from_f64(&shape, rhs.to_vec())?;
        let p0_t = Tensor::from_f64(&shape, p.to_vec())?;
        let rhs_key = format!("hyb_r{}_rhs", cfg.rank);
        let p0_key = format!("hyb_r{}_p0", cfg.rank);
        let out_key = format!("hyb_r{}_pred", cfg.rank);

        let mut pipe = Pipeline::new();
        pipe.put_tensor(&rhs_key, &rhs_t).put_tensor(&p0_key, &p0_t);
        for r in client.execute(pipe)? {
            r.expect_ok()?;
        }
        client.run_model(
            &cfg.model_key,
            &[rhs_key, p0_key],
            std::slice::from_ref(&out_key),
            cfg.device,
        )?;
        let pred = client.get_tensor(&out_key)?.to_f64()?;
        if pred.len() != g.n() {
            return Err(Error::Shape(format!(
                "surrogate returned {} values for a {}-cell grid",
                pred.len(),
                g.n()
            )));
        }

        // Score against the zero-mean-projected RHS — the same right-hand
        // side the numeric solver targets (the constant component of b is
        // outside the Laplacian's range, so it must not count as error).
        let mut b = rhs.to_vec();
        poisson::project_zero_mean(&mut b);
        let mut lap = vec![0.0; g.n()];
        poisson::apply_laplacian(g, &pred, &mut lap);
        let (mut rn, mut bn) = (0.0, 0.0);
        for i in 0..g.n() {
            let d = lap[i] - b[i];
            rn += d * d;
            bn += b[i] * b[i];
        }
        let residual = if bn > 0.0 { (rn / bn).sqrt() } else { rn.sqrt() };
        p.copy_from_slice(&pred);
        Ok(residual)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::client::Client;
    use crate::db::{DbServer, ServerConfig};

    #[test]
    fn hybrid_falls_back_then_accepts_improving_checkpoints() {
        let server = DbServer::start(ServerConfig::default()).unwrap();
        let mut publisher = Client::connect(server.addr).unwrap();
        let client = Client::connect(server.addr).unwrap();

        let mut flow = ChannelFlow::new(Grid::channel(12, 10, 8), 5e-3, 1, 0.08);
        let grid = flow.grid.clone();
        let mut hybrid = HybridSolver::new(client, HybridConfig::default());

        // No checkpoint published yet: the step must still complete, via
        // the numeric fallback, and count the inference failure.
        hybrid.step(&mut flow);
        assert_eq!(hybrid.stats.steps, 1);
        assert_eq!(hybrid.stats.accepted, 0);
        assert_eq!(hybrid.stats.fallbacks, 1);
        assert_eq!(hybrid.stats.surrogate_errors, 1);
        assert!(hybrid.last_error.as_deref().unwrap().contains("model not found"));

        // A weak checkpoint (2 iterations) predicts, but fails validation.
        let v1 = publisher
            .put_model(&hybrid.cfg.model_key, &poisson_model_text(&grid, 1e-9, 2))
            .unwrap();
        assert_eq!(v1, 1);
        hybrid.step(&mut flow);
        assert_eq!(hybrid.stats.fallbacks, 2);
        assert_eq!(hybrid.stats.accepted, 0);
        assert_eq!(hybrid.stats.residuals.count(), 1);
        assert!(hybrid.stats.residuals.max() > hybrid.cfg.accept_tol);

        // A converged checkpoint hot-swaps in; predictions now pass.
        let v2 = publisher
            .put_model(&hybrid.cfg.model_key, &poisson_model_text(&grid, 1e-8, 2000))
            .unwrap();
        assert_eq!(v2, 2);
        for _ in 0..3 {
            hybrid.step(&mut flow);
        }
        assert_eq!(hybrid.stats.steps, 5);
        assert_eq!(hybrid.stats.accepted, 3, "converged surrogate accepted");
        assert_eq!(hybrid.stats.fallbacks, 2);
        assert!(hybrid.stats.acceptance_rate() > 0.5);

        // The flow the hybrid advanced is still a valid projection step.
        let d = flow.mean_abs_divergence();
        assert!(d < 0.1, "hybrid-stepped divergence: {d}");
        assert_eq!(flow.step_no, 5);

        // And the registry saw the training loop: two versions, one swap.
        let entries = hybrid.client.list_models().unwrap();
        assert_eq!(entries.len(), 1);
        assert_eq!(entries[0].live_version, 2);
        assert_eq!(entries[0].swaps, 1);
        assert!(entries[0].executions >= 4, "weak + 3 converged runs");
    }

    #[test]
    fn accepted_step_matches_numeric_quality() {
        let server = DbServer::start(ServerConfig::default()).unwrap();
        let mut publisher = Client::connect(server.addr).unwrap();
        let client = Client::connect(server.addr).unwrap();

        let grid = Grid::channel(12, 10, 8);
        let mut numeric = ChannelFlow::new(grid.clone(), 5e-3, 7, 0.08);
        let mut hybrid_flow = ChannelFlow::new(grid.clone(), 5e-3, 7, 0.08);

        let cfg = HybridConfig { accept_tol: 1e-3, ..HybridConfig::default() };
        publisher.put_model(&cfg.model_key, &poisson_model_text(&grid, 1e-8, 2000)).unwrap();
        let mut hybrid = HybridSolver::new(client, cfg);

        for _ in 0..3 {
            numeric.step();
            hybrid.step(&mut hybrid_flow);
        }
        assert_eq!(hybrid.stats.accepted, 3);
        // The surrogate path must land on (essentially) the numeric
        // trajectory: both solve the same Poisson systems to tight
        // tolerance.
        let (dn, dh) = (numeric.mean_abs_divergence(), hybrid_flow.mean_abs_divergence());
        assert!(
            (dn - dh).abs() < 1e-2,
            "hybrid diverged from numeric trajectory: {dn} vs {dh}"
        );
    }
}
