//! Incompressible Navier-Stokes solver (the PHASTA stand-in).
//!
//! Fractional-step (projection) method on a uniform collocated grid:
//! periodic in x and z, no-slip walls in y, driven by a constant streamwise
//! body force — a plane channel.  Explicit 2nd-order advection/diffusion,
//! pressure Poisson via conjugate gradients.  The flow is initialized with a
//! laminar profile plus synthetic turbulent fluctuations (the flat-plate DNS
//! of the paper is seeded by synthetic turbulence generation the same way).

pub mod grid;
pub mod hybrid;
pub mod poisson;
pub mod producer;
pub mod sampler;
pub mod solver;
pub mod turbulence;

pub use grid::Grid;
pub use hybrid::{HybridConfig, HybridSolver, HybridStats};
pub use producer::{run_producer, CfdProducerConfig, CfdProducerOutcome};
pub use sampler::MeshSampler;
pub use solver::{ChannelFlow, SolverTimings};
