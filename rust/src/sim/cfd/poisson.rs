//! Pressure Poisson solver: conjugate gradients on the 7-point Laplacian
//! with periodic x/z and homogeneous Neumann walls in y.
//!
//! This is the "equation solution" component of the PHASTA stand-in — the
//! dominant cost of a time step (Table 1: 453 s solution vs 45 s formation).

use crate::sim::cfd::grid::Grid;

/// Apply the Laplacian: `out = ∇² p` with the solver's boundary conditions.
pub fn apply_laplacian(g: &Grid, p: &[f64], out: &mut [f64]) {
    let (dx2, dy2, dz2) = (g.dx() * g.dx(), g.dy() * g.dy(), g.dz() * g.dz());
    for k in 0..g.nz {
        for j in 0..g.ny {
            for i in 0..g.nx {
                let c = p[g.idx(i, j, k)];
                let xm = p[g.idx(g.im(i), j, k)];
                let xp = p[g.idx(g.ip(i), j, k)];
                // Neumann at the walls: ghost value mirrors the interior.
                let ym = if j == 0 { c } else { p[g.idx(i, j - 1, k)] };
                let yp = if j + 1 == g.ny { c } else { p[g.idx(i, j + 1, k)] };
                let zm = p[g.idx(i, j, g.km(k))];
                let zp = p[g.idx(i, j, g.kp(k))];
                out[g.idx(i, j, k)] =
                    (xm - 2.0 * c + xp) / dx2 + (ym - 2.0 * c + yp) / dy2 + (zm - 2.0 * c + zp) / dz2;
            }
        }
    }
}

fn dot(a: &[f64], b: &[f64]) -> f64 {
    a.iter().zip(b).map(|(x, y)| x * y).sum()
}

/// Remove the mean — the all-Neumann/periodic Laplacian has a constant
/// nullspace, so both the RHS and the solution are pinned to zero mean.
pub fn project_zero_mean(v: &mut [f64]) {
    let mean = v.iter().sum::<f64>() / v.len() as f64;
    for x in v.iter_mut() {
        *x -= mean;
    }
}

/// CG solve of `∇² p = rhs`.  Returns (iterations, final residual norm).
pub fn solve_cg(
    g: &Grid,
    rhs: &[f64],
    p: &mut [f64],
    tol: f64,
    max_iter: usize,
) -> (usize, f64) {
    let n = g.n();
    let mut b = rhs.to_vec();
    project_zero_mean(&mut b);
    project_zero_mean(p);

    let mut r = vec![0.0; n];
    let mut ap = vec![0.0; n];
    apply_laplacian(g, p, &mut ap);
    for i in 0..n {
        r[i] = b[i] - ap[i];
    }
    let mut d = r.clone();
    let mut rs = dot(&r, &r);
    let b_norm = dot(&b, &b).sqrt().max(1e-300);

    for it in 0..max_iter {
        let res = rs.sqrt();
        if res <= tol * b_norm {
            return (it, res);
        }
        apply_laplacian(g, &d, &mut ap);
        let dad = dot(&d, &ap);
        if dad.abs() < 1e-300 {
            return (it, res);
        }
        let alpha = rs / dad;
        for i in 0..n {
            p[i] += alpha * d[i];
            r[i] -= alpha * ap[i];
        }
        let rs_new = dot(&r, &r);
        let beta = rs_new / rs;
        rs = rs_new;
        for i in 0..n {
            d[i] = r[i] + beta * d[i];
        }
        // Keep the iterates in the zero-mean subspace (numerical drift).
        if it % 32 == 31 {
            project_zero_mean(p);
            project_zero_mean(&mut r);
        }
    }
    (max_iter, rs.sqrt())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn laplacian_of_constant_is_zero() {
        let g = Grid::channel(8, 8, 8);
        let p = vec![3.7; g.n()];
        let mut out = g.zeros();
        apply_laplacian(&g, &p, &mut out);
        assert!(out.iter().all(|v| v.abs() < 1e-12));
    }

    #[test]
    fn laplacian_is_symmetric_negative() {
        // <u, L v> == <L u, v> and <u, L u> <= 0: required for CG.
        let g = Grid::channel(6, 5, 4);
        let mut rng = Rng::new(3);
        let u: Vec<f64> = (0..g.n()).map(|_| rng.normal()).collect();
        let v: Vec<f64> = (0..g.n()).map(|_| rng.normal()).collect();
        let mut lu = g.zeros();
        let mut lv = g.zeros();
        apply_laplacian(&g, &u, &mut lu);
        apply_laplacian(&g, &v, &mut lv);
        let a = dot(&u, &lv);
        let b = dot(&lu, &v);
        assert!((a - b).abs() < 1e-9 * a.abs().max(1.0), "{a} vs {b}");
        assert!(dot(&u, &lu) <= 1e-12);
    }

    #[test]
    fn cg_solves_manufactured_problem() {
        // Manufactured solution: p = cos(2πx/Lx) (periodic, zero-mean,
        // satisfies Neumann trivially since dp/dy = 0).
        let g = Grid::channel(32, 16, 8);
        let mut p_exact = g.zeros();
        for k in 0..g.nz {
            for j in 0..g.ny {
                for i in 0..g.nx {
                    p_exact[g.idx(i, j, k)] =
                        (2.0 * std::f64::consts::PI * g.x(i) / g.lx).cos();
                }
            }
        }
        let mut rhs = g.zeros();
        apply_laplacian(&g, &p_exact, &mut rhs);
        let mut p = g.zeros();
        let (iters, res) = solve_cg(&g, &rhs, &mut p, 1e-10, 2000);
        assert!(iters < 2000, "converged in {iters}");
        assert!(res < 1e-8);
        let err: f64 = p
            .iter()
            .zip(&p_exact)
            .map(|(a, b)| (a - b) * (a - b))
            .sum::<f64>()
            .sqrt()
            / (g.n() as f64).sqrt();
        assert!(err < 1e-7, "rms error {err}");
    }

    #[test]
    fn cg_random_rhs_reaches_tolerance() {
        let g = Grid::channel(12, 10, 8);
        let mut rng = Rng::new(5);
        let mut rhs: Vec<f64> = (0..g.n()).map(|_| rng.normal()).collect();
        project_zero_mean(&mut rhs);
        let mut p = g.zeros();
        let (_iters, res) = solve_cg(&g, &rhs, &mut p, 1e-8, 5000);
        // Verify the residual claim independently.
        let mut lp = g.zeros();
        apply_laplacian(&g, &p, &mut lp);
        let rn: f64 = lp
            .iter()
            .zip(&rhs)
            .map(|(a, b)| (a - b) * (a - b))
            .sum::<f64>()
            .sqrt();
        let bn: f64 = rhs.iter().map(|x| x * x).sum::<f64>().sqrt();
        assert!(rn <= 1.1e-8 * bn + 1e-12, "residual {rn} vs {res}");
    }
}
