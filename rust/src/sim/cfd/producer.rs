//! The CFD publishing loop: couples the channel-flow solver (the PHASTA
//! stand-in) to the database through the adaptive publish governor.
//!
//! This is the paper's producer half of §4, factored out of the driver so
//! the same loop is reusable from benches and tests.  Every
//! `snapshot_every` solver steps each "PHASTA rank" samples the shared
//! flow onto its own mesh partition and publishes the snapshot:
//!
//! * **append mode** — step keys `{field}_rank{r}_step{s}`; memory is
//!   bounded by the store's retention window;
//! * **overwrite mode** — stable keys `{field}_rank{r}_latest`; bounded by
//!   construction.
//!
//! `Error::Busy` from a bounded store is *flow control*, not failure: the
//! [`PublishGovernor`] retries per its [`RetryPolicy`], and under
//! sustained pressure drops the snapshot and widens its publish stride
//! (skipped steps are merged into the next published snapshot, since the
//! solver keeps integrating).  `latest_step` only advances on a fully
//! published generation, so consumers never observe a partial one as
//! complete — a dropped generation's partial puts are simply overwritten
//! when its step id is reused by the next successful publish.

use std::net::SocketAddr;
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::time::Duration;

use crate::client::{stable_key, tensor_key, Client, DataStore, GovernorConfig, GovernorStats,
                    PublishGovernor};
use crate::error::Result;
use crate::sim::cfd::{ChannelFlow, Grid, MeshSampler};
use crate::telemetry::{ComponentTimes, Stopwatch};

/// Configuration of one CFD producer run (the driver assembles this from
/// [`crate::orchestrator::driver::InSituTrainingConfig`]).
#[derive(Debug, Clone)]
pub struct CfdProducerConfig {
    pub addr: SocketAddr,
    pub artifacts_dir: PathBuf,
    /// Solver grid (nx, ny, nz).
    pub grid: (usize, usize, usize),
    pub nu: f64,
    /// Simulated "PHASTA ranks" publishing partitions.
    pub sim_ranks: usize,
    /// Publish a snapshot every `snapshot_every` solver steps (paper: 2).
    pub snapshot_every: u64,
    /// Total solver steps to integrate.
    pub solver_steps: u64,
    pub seed: u64,
    /// Republish under stable keys instead of appending step keys.
    pub overwrite: bool,
    /// Busy backpressure handling (retry + adaptive skip).
    pub governor: GovernorConfig,
}

/// What a finished producer reports back to the driver.
#[derive(Debug, Clone, Copy)]
pub struct CfdProducerOutcome {
    /// Fully published generations (`latest_step` = `published - 1`).
    pub published: u64,
    /// Skip/retry/drop counters from the publish governor.
    pub governor: GovernorStats,
}

/// Run the producer loop until `solver_steps` are integrated or `stop` is
/// raised.  Component timings land in `times` (`client_init`, `send`,
/// `metadata`, `equation_formation`, `equation_solution`).
pub fn run_producer(
    cfg: &CfdProducerConfig,
    times: &ComponentTimes,
    stop: &AtomicBool,
) -> Result<CfdProducerOutcome> {
    let sampler = MeshSampler::load(&cfg.artifacts_dir.join("mesh_coords.bin"))?;
    let (nx, ny, nz) = cfg.grid;
    let mut flow = ChannelFlow::new(Grid::channel(nx, ny, nz), cfg.nu, cfg.seed, 0.12);

    let sw = Stopwatch::start();
    let mut clients: Vec<Client> = (0..cfg.sim_ranks)
        .map(|_| Client::connect_retry(cfg.addr, 100, Duration::from_millis(10)))
        .collect::<Result<_>>()?;
    times.record("client_init", sw.stop() / cfg.sim_ranks as f64);

    // Per-rank samplers: each "PHASTA rank" owns a partition, emulated by a
    // rank-seeded jitter of the shared mesh.
    let rank_samplers: Vec<MeshSampler> = (0..cfg.sim_ranks)
        .map(|r| {
            sampler.jittered(cfg.seed ^ (r as u64 + 1), [0.05, 0.02, 0.05], [3.99, 1.99, 1.99])
        })
        .collect();

    let mut governor = PublishGovernor::new(cfg.governor);
    let mut published = 0u64;
    for step in 0..cfg.solver_steps {
        if stop.load(Ordering::Relaxed) {
            break;
        }
        flow.step(); // formation+solution recorded in flow.timings
        if (step + 1) % cfg.snapshot_every != 0 {
            continue;
        }
        if !governor.should_publish() {
            // Under-pressure stride skip: this snapshot is merged into the
            // next published one (the solver state is cumulative).
            continue;
        }
        // Snapshots are sampled once; a Busy retry re-sends the same
        // buffers (idempotent overwrites).
        let snaps: Vec<_> = rank_samplers.iter().map(|rs| rs.snapshot(&flow)).collect();
        let placed = governor.publish(|| -> Result<()> {
            for (r, (client, snap)) in clients.iter_mut().zip(&snaps).enumerate() {
                let key = if cfg.overwrite {
                    stable_key("field", r)
                } else {
                    tensor_key("field", r, published)
                };
                let sw = Stopwatch::start();
                client.put_tensor(&key, snap)?;
                times.record("send", sw.stop());
            }
            Ok(())
        })?;
        if placed.is_some() {
            // Announce the generation only once every rank's snapshot is
            // resident — consumers never see a partial generation.
            let sw = Stopwatch::start();
            clients[0].put_meta("latest_step", &published.to_string())?;
            times.record("metadata", sw.stop());
            published += 1;
        }
    }

    // Fold the solver's internal timings in.
    for (name, acc) in [
        ("equation_formation", &flow.timings.formation),
        ("equation_solution", &flow.timings.solution),
    ] {
        // Per-sample statistics are lost; record the mean per step with the
        // count preserved via repeats.
        for _ in 0..acc.count() {
            times.record(name, acc.mean());
        }
    }
    Ok(CfdProducerOutcome { published, governor: governor.stats() })
}
