//! Samples solver fields onto the autoencoder's mesh points.
//!
//! The training mesh (python `mesh.py`, exported to `artifacts/
//! mesh_coords.bin`) is a stretched near-wall point set inside the channel.
//! Each "PHASTA rank" owns one such partition; the sampler trilinearly
//! interpolates (p, u, v, w) from the solver grid onto those points and
//! packs the `[4, N]` f32 tensor the training pipeline consumes.

use std::path::Path;

use crate::error::{Error, Result};
use crate::sim::cfd::grid::Grid;
use crate::sim::cfd::solver::ChannelFlow;
use crate::tensor::Tensor;

/// Mesh points loaded from the AOT artifacts.
#[derive(Debug, Clone)]
pub struct MeshSampler {
    /// [N][3] mesh coordinates.
    pub coords: Vec<[f64; 3]>,
}

impl MeshSampler {
    /// Load `mesh_coords.bin` (f32-LE, N*3).
    pub fn load(path: &Path) -> Result<MeshSampler> {
        let bytes = std::fs::read(path)
            .map_err(|e| Error::Parse(format!("read {}: {e}", path.display())))?;
        if bytes.len() % 12 != 0 {
            return Err(Error::Parse(format!(
                "mesh_coords.bin length {} not divisible by 12",
                bytes.len()
            )));
        }
        let mut coords = Vec::with_capacity(bytes.len() / 12);
        for c in bytes.chunks_exact(12) {
            let x = f32::from_le_bytes([c[0], c[1], c[2], c[3]]) as f64;
            let y = f32::from_le_bytes([c[4], c[5], c[6], c[7]]) as f64;
            let z = f32::from_le_bytes([c[8], c[9], c[10], c[11]]) as f64;
            coords.push([x, y, z]);
        }
        Ok(MeshSampler { coords })
    }

    /// Build directly from coordinates (tests, rank offsetting).
    pub fn from_coords(coords: Vec<[f64; 3]>) -> MeshSampler {
        MeshSampler { coords }
    }

    /// A per-rank variant of this mesh: every point jittered by a seeded
    /// uniform offset in `[0, jitter[d])`, clamped to `max[d]`.  The driver
    /// uses this to emulate each "PHASTA rank" owning its own partition —
    /// every rank publishes distinct data from the shared flow.
    pub fn jittered(&self, seed: u64, jitter: [f64; 3], max: [f64; 3]) -> MeshSampler {
        let mut rng = crate::util::rng::Rng::new(seed);
        let coords = self
            .coords
            .iter()
            .map(|c| {
                [
                    (c[0] + jitter[0] * rng.f64()).min(max[0]),
                    (c[1] + jitter[1] * rng.f64()).min(max[1]),
                    (c[2] + jitter[2] * rng.f64()).min(max[2]),
                ]
            })
            .collect();
        MeshSampler { coords }
    }

    pub fn n(&self) -> usize {
        self.coords.len()
    }

    /// Trilinear interpolation of a cell-centered field at a point
    /// (periodic x/z, clamped y).
    fn interp(g: &Grid, f: &[f64], p: [f64; 3]) -> f64 {
        let (dx, dy, dz) = (g.dx(), g.dy(), g.dz());
        // Continuous cell-center index.
        let fx = p[0] / dx - 0.5;
        let fy = (p[1] / dy - 0.5).clamp(0.0, (g.ny - 1) as f64);
        let fz = p[2] / dz - 0.5;
        let i0 = fx.floor();
        let j0 = fy.floor().min((g.ny - 2) as f64);
        let k0 = fz.floor();
        let (tx, ty, tz) = (fx - i0, fy - j0, fz - k0);
        let iw = |ii: f64| -> usize {
            let m = g.nx as isize;
            (((ii as isize) % m + m) % m) as usize
        };
        let kw = |kk: f64| -> usize {
            let m = g.nz as isize;
            (((kk as isize) % m + m) % m) as usize
        };
        let (i0u, i1u) = (iw(i0), iw(i0 + 1.0));
        let (j0u, j1u) = (j0 as usize, (j0 as usize + 1).min(g.ny - 1));
        let (k0u, k1u) = (kw(k0), kw(k0 + 1.0));
        let v = |i: usize, j: usize, k: usize| f[g.idx(i, j, k)];
        let c00 = v(i0u, j0u, k0u) * (1.0 - tx) + v(i1u, j0u, k0u) * tx;
        let c10 = v(i0u, j1u, k0u) * (1.0 - tx) + v(i1u, j1u, k0u) * tx;
        let c01 = v(i0u, j0u, k1u) * (1.0 - tx) + v(i1u, j0u, k1u) * tx;
        let c11 = v(i0u, j1u, k1u) * (1.0 - tx) + v(i1u, j1u, k1u) * tx;
        let c0 = c00 * (1.0 - ty) + c10 * ty;
        let c1 = c01 * (1.0 - ty) + c11 * ty;
        c0 * (1.0 - tz) + c1 * tz
    }

    /// Sample the instantaneous (p, u, v, w) snapshot as the `[4, N]` f32
    /// training tensor (channel order matches `model.py`).
    ///
    /// Packs little-endian bytes directly into the wire payload: the buffer
    /// built here is the exact allocation `put_tensor` sends and the
    /// database stores — no intermediate `Vec<f32>` or repack copy.
    pub fn snapshot(&self, flow: &ChannelFlow) -> Tensor {
        let n = self.n();
        let g = &flow.grid;
        let mut out = Vec::with_capacity(4 * 4 * n);
        for field in [&flow.p, &flow.u, &flow.v, &flow.w] {
            for pt in &self.coords {
                out.extend_from_slice(&(Self::interp(g, field, *pt) as f32).to_le_bytes());
            }
        }
        Tensor::from_le_bytes(crate::tensor::DType::F32, &[4, n], out)
            .expect("shape consistent by construction")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn interpolates_linear_field_exactly() {
        // f = 2y is linear => trilinear interpolation is exact in the
        // interior (away from the clamped wall layer).
        let g = Grid::channel(8, 16, 8);
        let mut f = g.zeros();
        for k in 0..g.nz {
            for j in 0..g.ny {
                for i in 0..g.nx {
                    f[g.idx(i, j, k)] = 2.0 * g.y(j);
                }
            }
        }
        for &y in &[0.3, 0.7, 1.0, 1.5] {
            let got = MeshSampler::interp(&g, &f, [1.0, y, 1.0]);
            assert!((got - 2.0 * y).abs() < 1e-12, "y={y}: {got}");
        }
    }

    #[test]
    fn periodic_wraparound_in_x() {
        let g = Grid::channel(8, 8, 8);
        let mut f = g.zeros();
        for k in 0..g.nz {
            for j in 0..g.ny {
                for i in 0..g.nx {
                    f[g.idx(i, j, k)] = (2.0 * std::f64::consts::PI * g.x(i) / g.lx).cos();
                }
            }
        }
        // Point just past the last cell center wraps smoothly.
        let a = MeshSampler::interp(&g, &f, [g.lx - 0.01, 1.0, 1.0]);
        let b = MeshSampler::interp(&g, &f, [0.01, 1.0, 1.0]);
        assert!((a - b).abs() < 0.1);
        assert!(a.is_finite() && b.is_finite());
    }

    #[test]
    fn jittered_is_deterministic_distinct_and_bounded() {
        let base = MeshSampler::from_coords(vec![[0.5, 0.5, 0.5], [3.9, 1.9, 1.9]]);
        let a = base.jittered(7, [0.05, 0.02, 0.05], [3.99, 1.99, 1.99]);
        let b = base.jittered(7, [0.05, 0.02, 0.05], [3.99, 1.99, 1.99]);
        let c = base.jittered(8, [0.05, 0.02, 0.05], [3.99, 1.99, 1.99]);
        assert_eq!(a.coords, b.coords, "same seed reproduces");
        assert_ne!(a.coords, c.coords, "ranks get distinct partitions");
        for (p, q) in base.coords.iter().zip(&a.coords) {
            for d in 0..3 {
                assert!(q[d] >= p[d] && q[d] <= [3.99, 1.99, 1.99][d], "{p:?} -> {q:?}");
            }
        }
    }

    #[test]
    fn snapshot_shape_and_channel_order() {
        let coords = vec![[0.5, 0.5, 0.5], [1.0, 1.0, 1.0], [2.0, 1.5, 0.3]];
        let s = MeshSampler::from_coords(coords);
        let flow = ChannelFlow::new(Grid::channel(8, 8, 8), 1e-2, 2, 0.05);
        let t = s.snapshot(&flow);
        assert_eq!(t.shape, vec![4, 3]);
        let v = t.to_f32().unwrap();
        // Channel 1 (u) should carry the mean flow: larger than channel 0
        // (p, ~0 at init).
        assert!(v[3..6].iter().all(|x| x.abs() > 1e-3), "u nonzero: {v:?}");
    }
}
