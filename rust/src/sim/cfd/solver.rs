//! The channel-flow Navier-Stokes stepper (fractional-step projection).
//!
//! Per step:
//!   1. **formation**: assemble the explicit advection-diffusion update
//!      `u* = u + dt (-(u·∇)u + ν ∇²u + f)` and the Poisson RHS `∇·u*/dt`,
//!   2. **solution**: CG-solve `∇²p = ∇·u*/dt`,
//!   3. projection `u = u* − dt ∇p` (folded into the formation timer; it is
//!      a vector axpy).
//!
//! The per-component timings feed Table 1's "Equation formation" /
//! "Equation solution" rows.

use crate::sim::cfd::grid::Grid;
use crate::sim::cfd::poisson;
use crate::sim::cfd::turbulence::SyntheticTurbulence;
use crate::telemetry::{StatAccum, Stopwatch};

/// Accumulated solver timings (paper Table 1 components).
#[derive(Debug, Default, Clone)]
pub struct SolverTimings {
    pub formation: StatAccum,
    pub solution: StatAccum,
}

/// Plane channel flow state.
pub struct ChannelFlow {
    pub grid: Grid,
    pub u: Vec<f64>,
    pub v: Vec<f64>,
    pub w: Vec<f64>,
    pub p: Vec<f64>,
    /// Kinematic viscosity.
    pub nu: f64,
    /// Constant streamwise body force (pressure-gradient drive).
    pub forcing: f64,
    pub dt: f64,
    pub step_no: u64,
    pub timings: SolverTimings,
    pub cg_tol: f64,
    pub cg_max_iter: usize,
    pub last_cg_iters: usize,
}

impl ChannelFlow {
    /// Initialize with a parabolic (Poiseuille) profile plus synthetic
    /// divergence-free fluctuations.
    pub fn new(grid: Grid, nu: f64, seed: u64, turb_intensity: f64) -> ChannelFlow {
        let turb = SyntheticTurbulence::new(seed, 96, 2.0, 12.0, turb_intensity);
        let n = grid.n();
        let mut u = vec![0.0; n];
        let mut v = vec![0.0; n];
        let mut w = vec![0.0; n];
        for k in 0..grid.nz {
            for j in 0..grid.ny {
                for i in 0..grid.nx {
                    let (x, y, z) = (grid.x(i), grid.y(j), grid.z(k));
                    let eta = y / grid.ly; // 0..1 across the channel
                    let base = 6.0 * eta * (1.0 - eta); // parabolic, max 1.5
                    // Wall-damped fluctuations (no-slip).
                    let damp = (4.0 * eta * (1.0 - eta)).clamp(0.0, 1.0);
                    let f = turb.eval([x, y, z]);
                    let id = grid.idx(i, j, k);
                    u[id] = base + f[0] * damp;
                    v[id] = f[1] * damp;
                    w[id] = f[2] * damp;
                }
            }
        }
        let dx = grid.dx().min(grid.dy()).min(grid.dz());
        // CFL-ish and diffusive limits for the explicit scheme.
        let dt = (0.2 * dx / 2.0).min(0.2 * dx * dx / (nu * 6.0));
        ChannelFlow {
            grid,
            u,
            v,
            w,
            p: vec![0.0; n],
            nu,
            forcing: 0.01,
            dt,
            step_no: 0,
            timings: SolverTimings::default(),
            cg_tol: 1e-6,
            cg_max_iter: 600,
            last_cg_iters: 0,
        }
    }

    fn enforce_walls(g: &Grid, u: &mut [f64], v: &mut [f64], w: &mut [f64]) {
        // No-slip at j = 0 and j = ny-1 (cell centers adjacent to the wall
        // are damped toward zero through the ghost treatment in derivatives;
        // we additionally clamp v at the wall-adjacent layer to kill
        // through-wall flow).
        for k in 0..g.nz {
            for i in 0..g.nx {
                let lo = g.idx(i, 0, k);
                let hi = g.idx(i, g.ny - 1, k);
                v[lo] = 0.0;
                v[hi] = 0.0;
                // Halve the tangential slip layer (a simple wall model).
                u[lo] *= 0.5;
                u[hi] *= 0.5;
                w[lo] *= 0.5;
                w[hi] *= 0.5;
            }
        }
    }

    /// First derivative, central, with wall-mirrored ghosts in y.
    #[inline]
    fn ddy(g: &Grid, f: &[f64], i: usize, j: usize, k: usize, wall_value: f64) -> f64 {
        let ym = if j == 0 { 2.0 * wall_value - f[g.idx(i, 0, k)] } else { f[g.idx(i, j - 1, k)] };
        let yp = if j + 1 == g.ny {
            2.0 * wall_value - f[g.idx(i, g.ny - 1, k)]
        } else {
            f[g.idx(i, j + 1, k)]
        };
        (yp - ym) / (2.0 * g.dy())
    }

    /// Advance one time step with the default CG pressure solve.  Returns
    /// the CG iteration count.
    pub fn step(&mut self) -> usize {
        let (tol, max_iter) = (self.cg_tol, self.cg_max_iter);
        self.step_with(|g, rhs, p| poisson::solve_cg(g, rhs, p, tol, max_iter))
    }

    /// Advance one time step with a caller-supplied pressure solve.
    ///
    /// The closure receives the grid, the Poisson RHS `∇·u*/dt`, and the
    /// pressure field (pre-populated with the previous step's solution, so
    /// iterative solvers get a warm start) and returns
    /// `(iterations, residual)`.  This is the seam the hybrid ML solver
    /// plugs into: it can answer with a surrogate prediction, a numeric
    /// solve, or a validated mix of the two.
    pub fn step_with<F>(&mut self, solve: F) -> usize
    where
        F: FnOnce(&Grid, &[f64], &mut [f64]) -> (usize, f64),
    {
        let g = self.grid.clone();
        let n = g.n();
        let (dx, dy2) = (g.dx(), g.dy() * g.dy());
        let (dx2, dz, dz2) = (dx * dx, g.dz(), g.dz() * g.dz());
        let dt = self.dt;
        let nu = self.nu;

        // ---- 1. formation: u* and Poisson RHS --------------------------
        let sw = Stopwatch::start();
        let mut us = vec![0.0; n];
        let mut vs = vec![0.0; n];
        let mut ws = vec![0.0; n];
        {
            let (u, v, w) = (&self.u, &self.v, &self.w);
            for k in 0..g.nz {
                for j in 0..g.ny {
                    for i in 0..g.nx {
                        let id = g.idx(i, j, k);
                        let (uc, vc, wc) = (u[id], v[id], w[id]);
                        // Central differences; walls use no-slip ghosts.
                        let fx = |f: &[f64]| {
                            (f[g.idx(g.ip(i), j, k)] - f[g.idx(g.im(i), j, k)]) / (2.0 * dx)
                        };
                        let fz = |f: &[f64]| {
                            (f[g.idx(i, j, g.kp(k))] - f[g.idx(i, j, g.km(k))]) / (2.0 * dz)
                        };
                        let lap = |f: &[f64]| {
                            let c = f[id];
                            let ym = if j == 0 { -c } else { f[g.idx(i, j - 1, k)] };
                            let yp = if j + 1 == g.ny { -c } else { f[g.idx(i, j + 1, k)] };
                            (f[g.idx(g.im(i), j, k)] - 2.0 * c + f[g.idx(g.ip(i), j, k)]) / dx2
                                + (ym - 2.0 * c + yp) / dy2
                                + (f[g.idx(i, j, g.km(k))] - 2.0 * c + f[g.idx(i, j, g.kp(k))]) / dz2
                        };
                        let adv_u =
                            uc * fx(u) + vc * Self::ddy(&g, u, i, j, k, 0.0) + wc * fz(u);
                        let adv_v =
                            uc * fx(v) + vc * Self::ddy(&g, v, i, j, k, 0.0) + wc * fz(v);
                        let adv_w =
                            uc * fx(w) + vc * Self::ddy(&g, w, i, j, k, 0.0) + wc * fz(w);
                        us[id] = uc + dt * (-adv_u + nu * lap(u) + self.forcing);
                        vs[id] = vc + dt * (-adv_v + nu * lap(v));
                        ws[id] = wc + dt * (-adv_w + nu * lap(w));
                    }
                }
            }
        }
        Self::enforce_walls(&g, &mut us, &mut vs, &mut ws);
        // Poisson RHS = div(u*) / dt.
        let mut rhs = vec![0.0; n];
        for k in 0..g.nz {
            for j in 0..g.ny {
                for i in 0..g.nx {
                    let id = g.idx(i, j, k);
                    let dudx =
                        (us[g.idx(g.ip(i), j, k)] - us[g.idx(g.im(i), j, k)]) / (2.0 * dx);
                    let dvdy = Self::ddy(&g, &vs, i, j, k, 0.0);
                    let dwdz =
                        (ws[g.idx(i, j, g.kp(k))] - ws[g.idx(i, j, g.km(k))]) / (2.0 * dz);
                    rhs[id] = (dudx + dvdy + dwdz) / dt;
                }
            }
        }
        self.timings.formation.add(sw.stop());

        // ---- 2. solution: pressure Poisson ------------------------------
        let sw = Stopwatch::start();
        let (iters, _res) = solve(&g, &rhs, &mut self.p);
        self.last_cg_iters = iters;
        self.timings.solution.add(sw.stop());

        // ---- 3. projection ----------------------------------------------
        let sw = Stopwatch::start();
        for k in 0..g.nz {
            for j in 0..g.ny {
                for i in 0..g.nx {
                    let id = g.idx(i, j, k);
                    let dpdx =
                        (self.p[g.idx(g.ip(i), j, k)] - self.p[g.idx(g.im(i), j, k)]) / (2.0 * dx);
                    let dpdy = Self::ddy(&g, &self.p, i, j, k, self.p[id]);
                    let dpdz =
                        (self.p[g.idx(i, j, g.kp(k))] - self.p[g.idx(i, j, g.km(k))]) / (2.0 * dz);
                    self.u[id] = us[id] - dt * dpdx;
                    self.v[id] = vs[id] - dt * dpdy;
                    self.w[id] = ws[id] - dt * dpdz;
                }
            }
        }
        Self::enforce_walls(&g, &mut self.u, &mut self.v, &mut self.w);
        // Projection is an axpy; fold into formation per Table 1's split.
        let t3 = sw.stop();
        self.timings.formation.add(t3);
        self.step_no += 1;
        iters
    }

    /// Volume-mean divergence magnitude (post-projection quality metric).
    pub fn mean_abs_divergence(&self) -> f64 {
        let g = &self.grid;
        let mut acc = 0.0;
        for k in 0..g.nz {
            for j in 0..g.ny {
                for i in 0..g.nx {
                    let dudx = (self.u[g.idx(g.ip(i), j, k)] - self.u[g.idx(g.im(i), j, k)])
                        / (2.0 * g.dx());
                    let dvdy = Self::ddy(g, &self.v, i, j, k, 0.0);
                    let dwdz = (self.w[g.idx(i, j, g.kp(k))] - self.w[g.idx(i, j, g.km(k))])
                        / (2.0 * g.dz());
                    acc += (dudx + dvdy + dwdz).abs();
                }
            }
        }
        acc / g.n() as f64
    }

    /// Kinetic energy per unit volume.
    pub fn kinetic_energy(&self) -> f64 {
        let mut acc = 0.0;
        for i in 0..self.grid.n() {
            acc += self.u[i] * self.u[i] + self.v[i] * self.v[i] + self.w[i] * self.w[i];
        }
        0.5 * acc / self.grid.n() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_flow() -> ChannelFlow {
        ChannelFlow::new(Grid::channel(12, 10, 8), 5e-3, 1, 0.08)
    }

    #[test]
    fn step_reduces_divergence() {
        let mut f = small_flow();
        f.step();
        let d = f.mean_abs_divergence();
        // Projection must leave a (discretely) nearly solenoidal field.  A
        // collocated central-difference projection cannot reach machine
        // zero (checkerboard nullspace), but it must stay small and must
        // not grow over steps.
        assert!(d < 0.1, "divergence after projection: {d}");
        for _ in 0..5 {
            f.step();
        }
        let d5 = f.mean_abs_divergence();
        assert!(d5 < 2.0 * d + 0.05, "divergence drifting: {d} -> {d5}");
    }

    #[test]
    fn energy_stays_bounded() {
        let mut f = small_flow();
        let e0 = f.kinetic_energy();
        for _ in 0..20 {
            f.step();
        }
        let e1 = f.kinetic_energy();
        assert!(e1.is_finite());
        assert!(e1 < 10.0 * e0 + 1.0, "blow-up: {e0} -> {e1}");
        assert!(e1 > 0.01 * e0, "flow died: {e0} -> {e1}");
    }

    #[test]
    fn timings_are_recorded() {
        let mut f = small_flow();
        f.step();
        f.step();
        assert_eq!(f.timings.solution.count(), 2);
        assert!(f.timings.formation.count() >= 2);
        assert!(f.timings.solution.mean() > 0.0);
    }

    #[test]
    fn deterministic_given_seed() {
        let mut a = small_flow();
        let mut b = small_flow();
        a.step();
        b.step();
        assert_eq!(a.u, b.u);
        assert_eq!(a.p, b.p);
    }

    #[test]
    fn no_through_wall_flow() {
        let mut f = small_flow();
        for _ in 0..5 {
            f.step();
        }
        let g = &f.grid;
        for k in 0..g.nz {
            for i in 0..g.nx {
                assert_eq!(f.v[g.idx(i, 0, k)], 0.0);
                assert_eq!(f.v[g.idx(i, g.ny - 1, k)], 0.0);
            }
        }
    }
}
