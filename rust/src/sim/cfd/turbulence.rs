//! Synthetic turbulence generation: divergence-free random Fourier modes.
//!
//! The paper's DNS is seeded with synthetic turbulence generation (Wright et
//! al. 2021).  We use the classical Kraichnan/Smirnov construction: a sum of
//! random Fourier modes with amplitudes shaped by a model spectrum and
//! directions projected to be divergence-free (`k · u_hat = 0` mode by
//! mode), so the sampled field is solenoidal by construction.

use crate::util::rng::Rng;

/// One synthetic mode.
#[derive(Debug, Clone)]
struct Mode {
    k: [f64; 3],
    amp: [f64; 3],
    phase: f64,
}

/// Divergence-free random velocity field generator.
#[derive(Debug, Clone)]
pub struct SyntheticTurbulence {
    modes: Vec<Mode>,
    pub intensity: f64,
}

impl SyntheticTurbulence {
    /// `n_modes` random modes with wavenumbers in `[k_min, k_max]` and a
    /// `k^-5/3` inertial-range amplitude envelope.
    pub fn new(seed: u64, n_modes: usize, k_min: f64, k_max: f64, intensity: f64) -> Self {
        let mut rng = Rng::new(seed);
        let mut modes = Vec::with_capacity(n_modes);
        for _ in 0..n_modes {
            // Random direction on the sphere, random magnitude in range.
            let mut k = [rng.normal(), rng.normal(), rng.normal()];
            let kn = (k[0] * k[0] + k[1] * k[1] + k[2] * k[2]).sqrt().max(1e-12);
            let mag = k_min + (k_max - k_min) * rng.f64();
            for x in k.iter_mut() {
                *x = *x / kn * mag;
            }
            // Random amplitude vector projected orthogonal to k (=> the mode
            // u = a * cos(k·x + φ) satisfies ∇·u = -a·k sin(...) = 0).
            let mut a = [rng.normal(), rng.normal(), rng.normal()];
            let ak = (a[0] * k[0] + a[1] * k[1] + a[2] * k[2]) / (mag * mag);
            for d in 0..3 {
                a[d] -= ak * k[d];
            }
            // k^-5/3 energy envelope.
            let env = (mag / k_min).powf(-5.0 / 6.0);
            for x in a.iter_mut() {
                *x *= env;
            }
            modes.push(Mode { k, amp: a, phase: rng.f64() * std::f64::consts::TAU });
        }
        // Normalize so the rms of each component is ~1 before scaling.
        let mut s = SyntheticTurbulence { modes, intensity: 1.0 };
        let rms = s.estimate_rms(seed ^ 0xabcd, 500);
        if rms > 1e-12 {
            for m in &mut s.modes {
                for x in m.amp.iter_mut() {
                    *x /= rms;
                }
            }
        }
        s.intensity = intensity;
        s
    }

    fn estimate_rms(&self, seed: u64, samples: usize) -> f64 {
        let mut rng = Rng::new(seed);
        let mut acc = 0.0;
        for _ in 0..samples {
            let p = [rng.f64() * 4.0, rng.f64() * 2.0, rng.f64() * 2.0];
            let v = self.eval_raw(p);
            acc += v[0] * v[0] + v[1] * v[1] + v[2] * v[2];
        }
        (acc / (3.0 * samples as f64)).sqrt()
    }

    fn eval_raw(&self, x: [f64; 3]) -> [f64; 3] {
        let mut u = [0.0; 3];
        for m in &self.modes {
            let ph = m.k[0] * x[0] + m.k[1] * x[1] + m.k[2] * x[2] + m.phase;
            let c = ph.cos();
            for d in 0..3 {
                u[d] += m.amp[d] * c;
            }
        }
        u
    }

    /// Velocity fluctuation at a point.
    pub fn eval(&self, x: [f64; 3]) -> [f64; 3] {
        let v = self.eval_raw(x);
        [v[0] * self.intensity, v[1] * self.intensity, v[2] * self.intensity]
    }

    /// Analytic divergence at a point (testing hook; ~0 by construction).
    pub fn divergence(&self, x: [f64; 3]) -> f64 {
        let mut div = 0.0;
        for m in &self.modes {
            let ph = m.k[0] * x[0] + m.k[1] * x[1] + m.k[2] * x[2] + m.phase;
            let s = -ph.sin();
            div += s * (m.amp[0] * m.k[0] + m.amp[1] * m.k[1] + m.amp[2] * m.k[2]);
        }
        div * self.intensity
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn divergence_free_by_construction() {
        let t = SyntheticTurbulence::new(11, 64, 1.0, 8.0, 0.1);
        let mut rng = Rng::new(99);
        for _ in 0..50 {
            let x = [rng.f64() * 4.0, rng.f64() * 2.0, rng.f64() * 2.0];
            assert!(t.divergence(x).abs() < 1e-10, "div {}", t.divergence(x));
        }
    }

    #[test]
    fn rms_close_to_intensity() {
        let t = SyntheticTurbulence::new(7, 128, 1.0, 8.0, 0.25);
        let mut rng = Rng::new(3);
        let mut acc = 0.0;
        let n = 2000;
        for _ in 0..n {
            let x = [rng.f64() * 4.0, rng.f64() * 2.0, rng.f64() * 2.0];
            let v = t.eval(x);
            acc += (v[0] * v[0] + v[1] * v[1] + v[2] * v[2]) / 3.0;
        }
        let rms = (acc / n as f64).sqrt();
        assert!((rms / 0.25 - 1.0).abs() < 0.25, "rms {rms} target 0.25");
    }

    #[test]
    fn deterministic_for_seed() {
        let a = SyntheticTurbulence::new(42, 32, 1.0, 4.0, 0.1);
        let b = SyntheticTurbulence::new(42, 32, 1.0, 4.0, 0.1);
        let x = [1.0, 0.5, 0.7];
        assert_eq!(a.eval(x), b.eval(x));
    }
}
