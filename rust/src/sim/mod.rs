//! Data producers.
//!
//! * [`cfd`] — a real (small) incompressible Navier-Stokes solver standing
//!   in for PHASTA (DESIGN.md substitutions): fractional-step projection
//!   with explicit advection/diffusion and a CG pressure Poisson solve, on a
//!   channel with synthetic-turbulence initialization.  Its cost naturally
//!   splits into the paper's Table-1 components ("equation formation" =
//!   RHS/assembly, "equation solution" = the linear solve).
//! * [`reproducer`] — the paper's §3 *simulation reproducer*: a rank that
//!   sleeps to emulate PDE integration, then sends/retrieves data through
//!   the SmartRedis-analogue client.  All scaling measurements use it,
//!   exactly as in the paper.

pub mod cfd;
pub mod reproducer;
