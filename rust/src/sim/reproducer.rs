//! The paper's §3 *simulation reproducer*, against the **real** database.
//!
//! A parallel program where every rank initializes a SmartRedis-analogue
//! client, then loops: sleep (emulating PDE integration), send its tensor,
//! retrieve it back.  For inference runs it additionally evaluates a model
//! through the RedisAI-analogue path.  All real-host measurements (Fig 4
//! small-scale points, Fig 7, and the CostModel calibration) come from here.

use std::net::SocketAddr;
use std::sync::Arc;
use std::time::Duration;

use crate::client::{tensor_key, Client, DataStore, RetryPolicy};
use crate::error::Result;
use crate::telemetry::{ComponentTimes, Stopwatch};
use crate::tensor::Tensor;
use crate::util::rng::Rng;

/// Configuration of a reproducer run.
#[derive(Debug, Clone)]
pub struct ReproducerConfig {
    pub addr: SocketAddr,
    pub ranks: usize,
    pub bytes_per_rank: usize,
    pub iterations: usize,
    pub warmup: usize,
    /// Emulated PDE-integration time per step.
    pub compute_secs: f64,
    /// How sends react to `Busy` backpressure from a bounded store
    /// (irrelevant on unbounded stores; the default fails immediately).
    pub retry: RetryPolicy,
}

/// Component timings aggregated across all ranks (mean ± σ, Tables 1-2
/// style).  Keys: `client_init`, `send`, `retrieve`.
pub fn run_data_loop(cfg: &ReproducerConfig) -> Result<Arc<ComponentTimes>> {
    let times = Arc::new(ComponentTimes::new());
    let mut handles = Vec::new();
    for rank in 0..cfg.ranks {
        let cfg = cfg.clone();
        let times = Arc::clone(&times);
        handles.push(std::thread::spawn(move || -> Result<()> {
            let mut rng = Rng::new(rank as u64 + 1);
            let n = cfg.bytes_per_rank / 4;
            let payload = Tensor::from_f32(&[n], rng.normal_vec_f32(n)).unwrap();

            let sw = Stopwatch::start();
            let mut client = Client::connect_retry(cfg.addr, 50, Duration::from_millis(20))?;
            times.record("client_init", sw.stop());

            for it in 0..cfg.warmup + cfg.iterations {
                let measuring = it >= cfg.warmup;
                if cfg.compute_secs > 0.0 {
                    std::thread::sleep(Duration::from_secs_f64(cfg.compute_secs));
                }
                let key = tensor_key("field", rank, it as u64);
                let sw = Stopwatch::start();
                let retries = client.put_tensor_retry(&key, &payload, &cfg.retry)?;
                if measuring {
                    times.record("send", sw.stop());
                    if retries > 0 {
                        times.record("busy_retries", retries as f64);
                    }
                }
                let sw = Stopwatch::start();
                let back = client.get_tensor(&key)?;
                if measuring {
                    times.record("retrieve", sw.stop());
                }
                debug_assert_eq!(back.nbytes(), payload.nbytes());
                // Keep the DB size bounded across iterations.
                client.del_tensor(&key)?;
            }
            Ok(())
        }));
    }
    for h in handles {
        h.join().expect("rank thread panicked")?;
    }
    Ok(times)
}

/// Inference reproducer: send input, `run_model`, retrieve predictions —
/// the three-step RedisAI flow of Fig 7, per rank per iteration.
#[derive(Debug, Clone)]
pub struct InferenceConfig {
    pub addr: SocketAddr,
    pub ranks: usize,
    pub model_key: String,
    /// Input tensor shape per request (e.g. [b, 3, 64, 64]).
    pub in_shape: Vec<usize>,
    pub iterations: usize,
    pub warmup: usize,
}

/// Keys: `client_init`, `send`, `eval`, `retrieve`, `total`.
pub fn run_inference_loop(cfg: &InferenceConfig) -> Result<Arc<ComponentTimes>> {
    let times = Arc::new(ComponentTimes::new());
    let mut handles = Vec::new();
    for rank in 0..cfg.ranks {
        let cfg = cfg.clone();
        let times = Arc::clone(&times);
        handles.push(std::thread::spawn(move || -> Result<()> {
            let mut rng = Rng::new(rank as u64 + 101);
            let n: usize = cfg.in_shape.iter().product();
            let input = Tensor::from_f32(&cfg.in_shape, rng.normal_vec_f32(n)).unwrap();
            let device = crate::ai::ModelRuntime::device_for_rank(rank);

            let sw = Stopwatch::start();
            let mut client = Client::connect_retry(cfg.addr, 50, Duration::from_millis(20))?;
            times.record("client_init", sw.stop());

            for it in 0..cfg.warmup + cfg.iterations {
                let measuring = it >= cfg.warmup;
                let in_key = tensor_key("infer_in", rank, it as u64);
                let out_key = tensor_key("infer_out", rank, it as u64);
                let sw_total = Stopwatch::start();

                let sw = Stopwatch::start();
                client.put_tensor(&in_key, &input)?;
                let t_send = sw.stop();

                let sw = Stopwatch::start();
                client.run_model(&cfg.model_key, &[in_key.clone()], &[out_key.clone()], device)?;
                let t_eval = sw.stop();

                let sw = Stopwatch::start();
                let _pred = client.get_tensor(&out_key)?;
                let t_retr = sw.stop();

                if measuring {
                    times.record("send", t_send);
                    times.record("eval", t_eval);
                    times.record("retrieve", t_retr);
                    times.record("total", sw_total.stop());
                }
                client.del_tensor(&in_key)?;
                client.del_tensor(&out_key)?;
            }
            Ok(())
        }));
    }
    for h in handles {
        h.join().expect("rank thread panicked")?;
    }
    Ok(times)
}

/// Tightly-coupled (in line) baseline for Fig 7: the simulation rank calls
/// the PJRT executable **directly in-process** — our analogue of the paper's
/// Fortran→C++ LibTorch bridge.  No database hop.
pub fn run_inline_baseline(
    exec: &crate::runtime::Executor,
    model_key: &str,
    in_shape: &[usize],
    iterations: usize,
    warmup: usize,
) -> Result<crate::telemetry::StatAccum> {
    let mut rng = Rng::new(7);
    let n: usize = in_shape.iter().product();
    let input = Tensor::from_f32(in_shape, rng.normal_vec_f32(n)).unwrap();
    let mut acc = crate::telemetry::StatAccum::new();
    for it in 0..warmup + iterations {
        let sw = Stopwatch::start();
        let _out = exec.execute(model_key, vec![input.clone()])?;
        if it >= warmup {
            acc.add(sw.stop());
        }
    }
    Ok(acc)
}
