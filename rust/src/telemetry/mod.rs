//! Timing and statistics instrumentation.
//!
//! The paper's evaluation reports per-component costs *averaged across ranks
//! with standard deviations* (Tables 1-2) and scaling series (Figs 3-8).
//! [`StatAccum`] accumulates one component's samples; [`ComponentTimes`]
//! aggregates named components across ranks; [`Table`] renders the
//! paper-style markdown/CSV rows the bench harnesses print.

pub mod table;

pub use table::{
    counter_table, failover_table, field_pressure_table, model_stats_table, models_table,
    serving_table, Table,
};

use std::collections::BTreeMap;
use std::sync::Mutex;
use std::time::Instant;

/// Streaming mean/variance/min/max accumulator (Welford).
#[derive(Debug, Clone, Default)]
pub struct StatAccum {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
    sum: f64,
}

impl StatAccum {
    pub fn new() -> Self {
        StatAccum { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY, sum: 0.0 }
    }

    pub fn add(&mut self, x: f64) {
        self.n += 1;
        self.sum += x;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn merge(&mut self, other: &StatAccum) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = other.clone();
            return;
        }
        let n1 = self.n as f64;
        let n2 = other.n as f64;
        let d = other.mean - self.mean;
        let n = n1 + n2;
        self.mean += d * n2 / n;
        self.m2 += other.m2 + d * d * n1 * n2 / n;
        self.n += other.n;
        self.sum += other.sum;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.mean
        }
    }

    pub fn sum(&self) -> f64 {
        self.sum
    }

    /// Population standard deviation.
    pub fn std(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            (self.m2 / self.n as f64).sqrt()
        }
    }

    pub fn min(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.min
        }
    }

    pub fn max(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.max
        }
    }
}

/// Scope timer: `let _t = Stopwatch::start(); ...; let dt = _t.stop();`
#[derive(Debug)]
pub struct Stopwatch(Instant);

impl Stopwatch {
    pub fn start() -> Self {
        Stopwatch(Instant::now())
    }

    /// Elapsed seconds.
    pub fn stop(&self) -> f64 {
        self.0.elapsed().as_secs_f64()
    }
}

/// Named per-component accumulators, shared across rank threads.
///
/// This is the Table-1/Table-2 instrument: every rank records its
/// `client initialization`, `metadata transfer`, `training data send`, ...
/// samples, and the report prints mean ± σ across ranks.
#[derive(Debug, Default)]
pub struct ComponentTimes {
    inner: Mutex<BTreeMap<String, StatAccum>>,
}

impl ComponentTimes {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(&self, component: &str, seconds: f64) {
        let mut m = self.inner.lock().unwrap();
        m.entry(component.to_string()).or_default().add(seconds);
    }

    /// Time a closure and record it.
    pub fn time<T>(&self, component: &str, f: impl FnOnce() -> T) -> T {
        let sw = Stopwatch::start();
        let out = f();
        self.record(component, sw.stop());
        out
    }

    pub fn get(&self, component: &str) -> Option<StatAccum> {
        self.inner.lock().unwrap().get(component).cloned()
    }

    pub fn snapshot(&self) -> BTreeMap<String, StatAccum> {
        self.inner.lock().unwrap().clone()
    }

    /// Paper-style table: component, average [sec], std-dev [sec].
    pub fn to_table(&self, title: &str) -> Table {
        let mut t = Table::new(
            title,
            &["Component", "Average [sec]", "Std Dev [sec]", "Samples"],
        );
        for (k, s) in self.snapshot() {
            t.row(&[
                k.clone(),
                format!("{:.6}", s.mean()),
                format!("{:.6}", s.std()),
                format!("{}", s.count()),
            ]);
        }
        t
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stat_accum_basics() {
        let mut s = StatAccum::new();
        for x in [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0] {
            s.add(x);
        }
        assert_eq!(s.count(), 8);
        assert!((s.mean() - 5.0).abs() < 1e-12);
        assert!((s.std() - 2.0).abs() < 1e-12);
        assert_eq!(s.min(), 2.0);
        assert_eq!(s.max(), 9.0);
    }

    #[test]
    fn merge_equals_concat() {
        let xs: Vec<f64> = (0..50).map(|i| (i as f64).sin() * 3.0).collect();
        let mut all = StatAccum::new();
        for x in &xs {
            all.add(*x);
        }
        let mut a = StatAccum::new();
        let mut b = StatAccum::new();
        for (i, x) in xs.iter().enumerate() {
            if i % 2 == 0 {
                a.add(*x)
            } else {
                b.add(*x)
            }
        }
        a.merge(&b);
        assert_eq!(a.count(), all.count());
        assert!((a.mean() - all.mean()).abs() < 1e-12);
        assert!((a.std() - all.std()).abs() < 1e-12);
    }

    #[test]
    fn empty_accum_is_quiet() {
        let s = StatAccum::new();
        assert_eq!(s.mean(), 0.0);
        assert_eq!(s.std(), 0.0);
        assert_eq!(s.count(), 0);
    }

    #[test]
    fn component_times_records() {
        let ct = ComponentTimes::new();
        ct.record("send", 0.1);
        ct.record("send", 0.3);
        ct.record("retrieve", 0.2);
        let snap = ct.snapshot();
        assert_eq!(snap.len(), 2);
        assert!((snap["send"].mean() - 0.2).abs() < 1e-12);
        let out = ct.to_table("t").render_markdown();
        assert!(out.contains("send"));
        assert!(out.contains("retrieve"));
    }

    #[test]
    fn stopwatch_monotone() {
        let sw = Stopwatch::start();
        std::thread::sleep(std::time::Duration::from_millis(5));
        assert!(sw.stop() >= 0.004);
    }
}
