//! Paper-style result tables rendered as markdown (for EXPERIMENTS.md) and
//! CSV (for plotting), plus the canned tables `situ info` and the run
//! reports use for retention pressure and backpressure counters.

use crate::proto::{DbInfo, Device, ModelDeviceStat, ModelEntry};
use crate::util::fmt;

/// A simple titled table.
#[derive(Debug, Clone)]
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells.to_vec());
    }

    pub fn row_strs(&mut self, cells: &[&str]) {
        self.row(&cells.iter().map(|s| s.to_string()).collect::<Vec<_>>());
    }

    /// Column-aligned markdown block with the title as a heading.
    pub fn render_markdown(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = format!("### {}\n\n", self.title);
        let fmt_row = |cells: &[String]| -> String {
            let mut line = String::from("|");
            for (i, c) in cells.iter().enumerate() {
                line.push_str(&format!(" {:<w$} |", c, w = widths[i]));
            }
            line.push('\n');
            line
        };
        out.push_str(&fmt_row(&self.headers));
        let mut sep = String::from("|");
        for w in &widths {
            sep.push_str(&format!("{:-<w$}|", "", w = w + 2));
        }
        sep.push('\n');
        out.push_str(&sep);
        for r in &self.rows {
            out.push_str(&fmt_row(r));
        }
        out
    }

    /// CSV with a `# title` comment line.
    pub fn render_csv(&self) -> String {
        let esc = |s: &str| -> String {
            if s.contains(',') || s.contains('"') || s.contains('\n') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.to_string()
            }
        };
        let mut out = format!("# {}\n", self.title);
        out.push_str(&self.headers.iter().map(|h| esc(h)).collect::<Vec<_>>().join(","));
        out.push('\n');
        for r in &self.rows {
            out.push_str(&r.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","));
            out.push('\n');
        }
        out
    }

    /// Print markdown to stdout (bench harness convention).
    pub fn print(&self) {
        println!("{}", self.render_markdown());
    }
}

/// Per-field memory-pressure table from an `INFO` reply: resident bytes
/// (and share of the byte cap when one is set), resident generations,
/// eviction counters, and spill-to-disk cold-tier counters.  Empty
/// retention state renders an empty table — callers usually skip printing
/// it when `info.fields` is empty.
pub fn field_pressure_table(info: &DbInfo) -> Table {
    let mut t = Table::new(
        "per-field retention pressure",
        &[
            "field",
            "resident",
            "of cap",
            "generations",
            "evicted keys",
            "evicted bytes",
            "spilled keys",
            "spilled bytes",
        ],
    );
    for f in &info.fields {
        let of_cap = if info.retention_max_bytes > 0 {
            format!(
                "{:.1}%",
                100.0 * f.resident_bytes as f64 / info.retention_max_bytes as f64
            )
        } else {
            "-".to_string()
        };
        t.row(&[
            f.field.clone(),
            fmt::bytes(f.resident_bytes),
            of_cap,
            f.generations.to_string(),
            f.evicted_keys.to_string(),
            fmt::bytes(f.evicted_bytes),
            f.spilled_keys.to_string(),
            fmt::bytes(f.spilled_bytes),
        ]);
    }
    t
}

/// One-column-per-name counter table — the rendering behind the
/// backpressure (skip/retry/drop) report lines of `situ info`, the CFD
/// producer, and the trainer's final report.
pub fn counter_table(title: &str, counters: &[(&str, u64)]) -> Table {
    let mut t = Table::new(title, &["counter", "value"]);
    for (name, value) in counters {
        t.row(&[name.to_string(), value.to_string()]);
    }
    t
}

/// Client-side replication/failover counters (populated by
/// [`crate::client::ClusterClient::info`]; single servers report zeros).
pub fn failover_table(info: &DbInfo) -> Table {
    counter_table(
        "replication / failover",
        &[
            ("replicated writes", info.replicated_writes),
            ("read failovers", info.read_failovers),
            ("shard reconnects", info.shard_reconnects),
            ("degraded ops (partial shard errors)", info.degraded_ops),
        ],
    )
}

/// Registry contents from a `ListModels` reply: one row per model key with
/// its live version, how many immutable versions are retained, how often
/// the live pointer was hot-swapped, and total backend executions.
pub fn models_table(entries: &[ModelEntry]) -> Table {
    let mut t = Table::new(
        "model registry",
        &["key", "live version", "kept versions", "swaps", "executions"],
    );
    for e in entries {
        t.row(&[
            e.key.clone(),
            format!("v{}", e.live_version),
            e.n_versions.to_string(),
            e.swaps.to_string(),
            e.executions.to_string(),
        ]);
    }
    t
}

/// Per-device serving statistics from a `ModelStats` reply: executions,
/// eval wall-time and GPU-slot queue-wait distributions.
pub fn model_stats_table(stats: &[ModelDeviceStat]) -> Table {
    let mut t = Table::new(
        "model serving by device",
        &["device", "executions", "eval mean", "eval std", "queue mean", "queue std"],
    );
    for s in stats {
        let dev = match s.device {
            Device::Cpu => "cpu".to_string(),
            Device::Gpu(i) => format!("gpu{i}"),
        };
        t.row(&[
            dev,
            s.executions.to_string(),
            fmt::duration(s.eval_mean_s),
            fmt::duration(s.eval_std_s),
            fmt::duration(s.queue_mean_s),
            fmt::duration(s.queue_std_s),
        ]);
    }
    t
}

/// Serving-side counters from an `INFO` reply: hot-swaps plus the adaptive
/// micro-batcher's coalescing effectiveness.
pub fn serving_table(info: &DbInfo) -> Table {
    counter_table(
        "model serving",
        &[
            ("live models", info.models),
            ("model hot-swaps", info.model_swaps),
            ("coalesced batches", info.batches),
            ("requests served batched", info.batched_requests),
        ],
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn markdown_alignment() {
        let mut t = Table::new("Demo", &["name", "value"]);
        t.row_strs(&["send", "0.120"]);
        t.row_strs(&["a-much-longer-component", "1"]);
        let md = t.render_markdown();
        assert!(md.starts_with("### Demo"));
        assert!(md.contains("| send"));
        let lines: Vec<&str> = md.lines().filter(|l| l.starts_with('|')).collect();
        // All body lines equal width.
        assert!(lines.windows(2).all(|w| w[0].len() == w[1].len()));
    }

    #[test]
    fn csv_escaping() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row_strs(&["v,1", "say \"hi\""]);
        let csv = t.render_csv();
        assert!(csv.contains("\"v,1\""));
        assert!(csv.contains("\"say \"\"hi\"\"\""));
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn arity_checked() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row_strs(&["only-one"]);
    }

    #[test]
    fn field_pressure_table_renders_cap_share() {
        use crate::proto::FieldPressure;
        let info = DbInfo {
            retention_max_bytes: 1000,
            fields: vec![FieldPressure {
                field: "u".into(),
                resident_bytes: 250,
                generations: 2,
                evicted_keys: 3,
                evicted_bytes: 750,
                spilled_keys: 3,
                spilled_bytes: 750,
            }],
            ..Default::default()
        };
        let md = field_pressure_table(&info).render_markdown();
        assert!(md.contains("| u"), "{md}");
        assert!(md.contains("25.0%"), "resident share of the cap:\n{md}");
        assert!(md.contains("| 2 "), "generation count:\n{md}");
        assert!(md.contains("spilled keys"), "cold-tier columns present:\n{md}");
        // Without a cap the share column is a dash.
        let info = DbInfo { fields: info.fields, ..Default::default() };
        assert!(field_pressure_table(&info).render_markdown().contains("| -"));
    }

    #[test]
    fn counter_table_rows() {
        let md = counter_table("backpressure", &[("skipped", 4), ("retries", 7)])
            .render_markdown();
        assert!(md.contains("skipped"));
        assert!(md.contains("| 7"));
    }

    #[test]
    fn serving_tables_render() {
        let entries = vec![ModelEntry {
            key: "surrogate".into(),
            live_version: 3,
            n_versions: 2,
            swaps: 2,
            executions: 40,
        }];
        let md = models_table(&entries).render_markdown();
        assert!(md.contains("| surrogate"), "{md}");
        assert!(md.contains("v3"), "{md}");
        assert!(md.contains("| 40"), "{md}");

        let stats = vec![ModelDeviceStat {
            device: Device::Gpu(1),
            executions: 7,
            eval_count: 7,
            eval_mean_s: 0.001,
            eval_std_s: 0.0,
            queue_count: 7,
            queue_mean_s: 0.0,
            queue_std_s: 0.0,
        }];
        let md = model_stats_table(&stats).render_markdown();
        assert!(md.contains("gpu1"), "{md}");
        assert!(md.contains("| 7"), "{md}");

        let info = DbInfo {
            models: 2,
            model_swaps: 3,
            batches: 5,
            batched_requests: 17,
            ..Default::default()
        };
        let md = serving_table(&info).render_markdown();
        assert!(md.contains("model hot-swaps"), "{md}");
        assert!(md.contains("| 17"), "{md}");
    }

    #[test]
    fn failover_table_rows() {
        let info = DbInfo {
            replicated_writes: 12,
            read_failovers: 3,
            shard_reconnects: 1,
            degraded_ops: 2,
            ..Default::default()
        };
        let md = failover_table(&info).render_markdown();
        assert!(md.contains("replicated writes"), "{md}");
        assert!(md.contains("| 12"), "{md}");
        assert!(md.contains("read failovers"), "{md}");
        assert!(md.contains("shard reconnects"), "{md}");
        assert!(md.contains("degraded ops"), "{md}");
    }
}
