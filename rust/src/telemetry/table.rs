//! Paper-style result tables rendered as markdown (for EXPERIMENTS.md) and
//! CSV (for plotting).

/// A simple titled table.
#[derive(Debug, Clone)]
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells.to_vec());
    }

    pub fn row_strs(&mut self, cells: &[&str]) {
        self.row(&cells.iter().map(|s| s.to_string()).collect::<Vec<_>>());
    }

    /// Column-aligned markdown block with the title as a heading.
    pub fn render_markdown(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = format!("### {}\n\n", self.title);
        let fmt_row = |cells: &[String]| -> String {
            let mut line = String::from("|");
            for (i, c) in cells.iter().enumerate() {
                line.push_str(&format!(" {:<w$} |", c, w = widths[i]));
            }
            line.push('\n');
            line
        };
        out.push_str(&fmt_row(&self.headers));
        let mut sep = String::from("|");
        for w in &widths {
            sep.push_str(&format!("{:-<w$}|", "", w = w + 2));
        }
        sep.push('\n');
        out.push_str(&sep);
        for r in &self.rows {
            out.push_str(&fmt_row(r));
        }
        out
    }

    /// CSV with a `# title` comment line.
    pub fn render_csv(&self) -> String {
        let esc = |s: &str| -> String {
            if s.contains(',') || s.contains('"') || s.contains('\n') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.to_string()
            }
        };
        let mut out = format!("# {}\n", self.title);
        out.push_str(&self.headers.iter().map(|h| esc(h)).collect::<Vec<_>>().join(","));
        out.push('\n');
        for r in &self.rows {
            out.push_str(&r.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","));
            out.push('\n');
        }
        out
    }

    /// Print markdown to stdout (bench harness convention).
    pub fn print(&self) {
        println!("{}", self.render_markdown());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn markdown_alignment() {
        let mut t = Table::new("Demo", &["name", "value"]);
        t.row_strs(&["send", "0.120"]);
        t.row_strs(&["a-much-longer-component", "1"]);
        let md = t.render_markdown();
        assert!(md.starts_with("### Demo"));
        assert!(md.contains("| send"));
        let lines: Vec<&str> = md.lines().filter(|l| l.starts_with('|')).collect();
        // All body lines equal width.
        assert!(lines.windows(2).all(|w| w[0].len() == w[1].len()));
    }

    #[test]
    fn csv_escaping() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row_strs(&["v,1", "say \"hi\""]);
        let csv = t.render_csv();
        assert!(csv.contains("\"v,1\""));
        assert!(csv.contains("\"say \"\"hi\"\"\""));
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn arity_checked() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row_strs(&["only-one"]);
    }
}
