//! Dense tensor value type carried through the database and the wire
//! protocol.  Row-major, little-endian payload; the dtype set matches what
//! the AOT artifacts exchange (f32 everywhere, i32 for the step counter).
//!
//! The payload is a shared [`Bytes`] buffer: cloning a `Tensor` (and
//! therefore every `Store::get_tensor`, dataloader gather, and model-input
//! fan-out in the crate) bumps a refcount instead of copying megabytes.
//! `Request::decode_shared` goes further and makes the payload a *view into
//! the wire frame itself*, so a `put_tensor` travels socket → store with a
//! single payload allocation end to end.

use std::fmt;

use crate::error::{Error, Result};
pub use crate::util::bytes::Bytes;

/// Element type of a [`Tensor`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum DType {
    F32,
    F64,
    I32,
    U8,
}

impl DType {
    pub fn size(self) -> usize {
        match self {
            DType::F32 | DType::I32 => 4,
            DType::F64 => 8,
            DType::U8 => 1,
        }
    }

    pub fn tag(self) -> u8 {
        match self {
            DType::F32 => 0,
            DType::F64 => 1,
            DType::I32 => 2,
            DType::U8 => 3,
        }
    }

    pub fn from_tag(t: u8) -> Result<DType> {
        Ok(match t {
            0 => DType::F32,
            1 => DType::F64,
            2 => DType::I32,
            3 => DType::U8,
            _ => return Err(Error::Protocol(format!("unknown dtype tag {t}"))),
        })
    }

    /// Name as it appears in the AOT manifest (`numpy` dtype strings).
    pub fn from_manifest(s: &str) -> Result<DType> {
        Ok(match s {
            "float32" => DType::F32,
            "float64" => DType::F64,
            "int32" => DType::I32,
            "uint8" => DType::U8,
            _ => return Err(Error::Parse(format!("unsupported manifest dtype '{s}'"))),
        })
    }
}

impl fmt::Display for DType {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let s = match self {
            DType::F32 => "f32",
            DType::F64 => "f64",
            DType::I32 => "i32",
            DType::U8 => "u8",
        };
        f.write_str(s)
    }
}

/// A dense, row-major tensor (shape + raw little-endian payload).
///
/// Clones are cheap: the payload is shared by refcount, never deep-copied.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub dtype: DType,
    pub shape: Vec<usize>,
    pub data: Bytes,
}

impl Tensor {
    /// Number of elements.
    pub fn len(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Payload size in bytes.
    pub fn nbytes(&self) -> usize {
        self.data.len()
    }

    pub fn zeros(dtype: DType, shape: &[usize]) -> Tensor {
        let n: usize = shape.iter().product();
        Tensor {
            dtype,
            shape: shape.to_vec(),
            data: Bytes::from_vec(vec![0u8; n * dtype.size()]),
        }
    }

    /// Build from a raw little-endian payload, taking ownership without a
    /// copy when handed a `Vec<u8>` or an existing [`Bytes`] view.
    pub fn from_le_bytes(dtype: DType, shape: &[usize], data: impl Into<Bytes>) -> Result<Tensor> {
        let t = Tensor { dtype, shape: shape.to_vec(), data: data.into() };
        t.validate()?;
        Ok(t)
    }

    pub fn from_f32(shape: &[usize], values: Vec<f32>) -> Result<Tensor> {
        let n: usize = shape.iter().product();
        if n != values.len() {
            return Err(Error::Shape(format!(
                "shape {:?} wants {} elements, got {}",
                shape,
                n,
                values.len()
            )));
        }
        let mut data = Vec::with_capacity(n * 4);
        for v in values {
            data.extend_from_slice(&v.to_le_bytes());
        }
        Ok(Tensor { dtype: DType::F32, shape: shape.to_vec(), data: Bytes::from_vec(data) })
    }

    pub fn from_f64(shape: &[usize], values: Vec<f64>) -> Result<Tensor> {
        let n: usize = shape.iter().product();
        if n != values.len() {
            return Err(Error::Shape(format!(
                "shape {:?} wants {} elements, got {}",
                shape,
                n,
                values.len()
            )));
        }
        let mut data = Vec::with_capacity(n * 8);
        for v in values {
            data.extend_from_slice(&v.to_le_bytes());
        }
        Ok(Tensor { dtype: DType::F64, shape: shape.to_vec(), data: Bytes::from_vec(data) })
    }

    pub fn from_i32(shape: &[usize], values: Vec<i32>) -> Result<Tensor> {
        let n: usize = shape.iter().product();
        if n != values.len() {
            return Err(Error::Shape(format!(
                "shape {:?} wants {} elements, got {}",
                shape,
                n,
                values.len()
            )));
        }
        let mut data = Vec::with_capacity(n * 4);
        for v in values {
            data.extend_from_slice(&v.to_le_bytes());
        }
        Ok(Tensor { dtype: DType::I32, shape: shape.to_vec(), data: Bytes::from_vec(data) })
    }

    pub fn scalar_f32(v: f32) -> Tensor {
        Tensor::from_f32(&[], vec![v]).unwrap()
    }

    pub fn scalar_i32(v: i32) -> Tensor {
        Tensor::from_i32(&[], vec![v]).unwrap()
    }

    /// Decode the payload as f32s (copies; the wire buffer is unaligned).
    pub fn to_f32(&self) -> Result<Vec<f32>> {
        if self.dtype != DType::F32 {
            return Err(Error::Shape(format!("tensor is {}, wanted f32", self.dtype)));
        }
        Ok(self
            .data
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    pub fn to_f64(&self) -> Result<Vec<f64>> {
        if self.dtype != DType::F64 {
            return Err(Error::Shape(format!("tensor is {}, wanted f64", self.dtype)));
        }
        Ok(self
            .data
            .chunks_exact(8)
            .map(|c| f64::from_le_bytes([c[0], c[1], c[2], c[3], c[4], c[5], c[6], c[7]]))
            .collect())
    }

    pub fn to_i32(&self) -> Result<Vec<i32>> {
        if self.dtype != DType::I32 {
            return Err(Error::Shape(format!("tensor is {}, wanted i32", self.dtype)));
        }
        Ok(self
            .data
            .chunks_exact(4)
            .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    /// First element as f32 (scalars from model outputs).
    pub fn first_f32(&self) -> Result<f32> {
        let c = self
            .data
            .get(0..4)
            .ok_or_else(|| Error::Shape("empty tensor".into()))?;
        Ok(f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
    }

    /// Mean/min/max of an f32 tensor (telemetry).
    pub fn f32_stats(&self) -> Result<(f32, f32, f32)> {
        let v = self.to_f32()?;
        if v.is_empty() {
            return Err(Error::Shape("empty tensor".into()));
        }
        let mut mn = f32::INFINITY;
        let mut mx = f32::NEG_INFINITY;
        let mut sum = 0.0f64;
        for x in &v {
            mn = mn.min(*x);
            mx = mx.max(*x);
            sum += *x as f64;
        }
        Ok(((sum / v.len() as f64) as f32, mn, mx))
    }

    /// Validate payload length against shape/dtype (wire ingress check).
    pub fn validate(&self) -> Result<()> {
        let want = self.len() * self.dtype.size();
        if want != self.data.len() {
            return Err(Error::Shape(format!(
                "payload {} bytes, shape {:?} x {} wants {}",
                self.data.len(),
                self.shape,
                self.dtype,
                want
            )));
        }
        Ok(())
    }
}

impl fmt::Display for Tensor {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "Tensor<{}>{:?} ({})",
            self.dtype,
            self.shape,
            crate::util::fmt::bytes(self.nbytes() as u64)
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f32_roundtrip() {
        let t = Tensor::from_f32(&[2, 3], vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]).unwrap();
        assert_eq!(t.len(), 6);
        assert_eq!(t.nbytes(), 24);
        assert_eq!(t.to_f32().unwrap(), vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        t.validate().unwrap();
    }

    #[test]
    fn shape_mismatch_rejected() {
        assert!(Tensor::from_f32(&[2, 2], vec![1.0]).is_err());
        assert!(Tensor::from_f64(&[3], vec![1.0]).is_err());
    }

    #[test]
    fn f64_roundtrip() {
        let t = Tensor::from_f64(&[3], vec![1.5, -2.5, 1e300]).unwrap();
        assert_eq!(t.nbytes(), 24);
        assert_eq!(t.to_f64().unwrap(), vec![1.5, -2.5, 1e300]);
        assert!(t.to_f32().is_err());
        t.validate().unwrap();
    }

    #[test]
    fn scalar_shapes() {
        let s = Tensor::scalar_f32(3.5);
        assert_eq!(s.shape, Vec::<usize>::new());
        assert_eq!(s.len(), 1);
        assert_eq!(s.first_f32().unwrap(), 3.5);
        let i = Tensor::scalar_i32(-7);
        assert_eq!(i.to_i32().unwrap(), vec![-7]);
    }

    #[test]
    fn zeros_and_validate() {
        let t = Tensor::zeros(DType::F64, &[4, 4]);
        assert_eq!(t.nbytes(), 128);
        t.validate().unwrap();
        let mut bad = t.clone();
        bad.data = bad.data.slice(0..bad.data.len() - 1);
        assert!(bad.validate().is_err());
    }

    #[test]
    fn clone_shares_payload_allocation() {
        let t = Tensor::from_f32(&[4], vec![1.0, 2.0, 3.0, 4.0]).unwrap();
        let c = t.clone();
        assert!(c.data.shares_allocation(&t.data), "clone must not deep-copy");
        assert_eq!(c.data.as_ptr(), t.data.as_ptr());
        assert_eq!(c, t);
    }

    #[test]
    fn from_le_bytes_takes_ownership() {
        let raw: Vec<u8> = 1.5f32.to_le_bytes().to_vec();
        let ptr = raw.as_ptr();
        let t = Tensor::from_le_bytes(DType::F32, &[1], raw).unwrap();
        assert_eq!(t.data.as_ptr(), ptr, "no copy on ingest");
        assert_eq!(t.to_f32().unwrap(), vec![1.5]);
        assert!(Tensor::from_le_bytes(DType::F32, &[2], vec![0u8; 4]).is_err());
    }

    #[test]
    fn stats() {
        let t = Tensor::from_f32(&[4], vec![-1.0, 0.0, 1.0, 4.0]).unwrap();
        let (mean, mn, mx) = t.f32_stats().unwrap();
        assert_eq!(mean, 1.0);
        assert_eq!(mn, -1.0);
        assert_eq!(mx, 4.0);
    }

    #[test]
    fn dtype_tags_roundtrip() {
        for d in [DType::F32, DType::F64, DType::I32, DType::U8] {
            assert_eq!(DType::from_tag(d.tag()).unwrap(), d);
        }
        assert!(DType::from_tag(99).is_err());
    }

    #[test]
    fn wrong_dtype_decode_rejected() {
        let t = Tensor::zeros(DType::I32, &[2]);
        assert!(t.to_f32().is_err());
        assert!(t.to_i32().is_ok());
    }
}
