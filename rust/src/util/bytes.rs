//! Cheap-to-clone shared byte buffer backing tensor payloads.
//!
//! The zero-copy data plane (see [`crate::proto`] and [`crate::db::store`])
//! needs one payload allocation to be visible from several places at once:
//! the frame body read off a socket, the tensor stored in the database, and
//! every outstanding `get_tensor` result.  `Bytes` is an `Arc`-backed,
//! immutable byte buffer with an offset/len view — cloning or slicing it is
//! a refcount bump, never a memcpy.  Overwriting or deleting a store entry
//! drops one reference; readers still holding a view keep the old
//! allocation alive and fully valid (no torn reads, no use-after-free).

use std::fmt;
use std::ops::{Deref, Range};
use std::sync::Arc;

/// An immutable, reference-counted byte buffer view.
#[derive(Clone)]
pub struct Bytes {
    buf: Arc<Vec<u8>>,
    off: usize,
    len: usize,
}

impl Bytes {
    /// An empty buffer.
    pub fn new() -> Bytes {
        Bytes::from_vec(Vec::new())
    }

    /// Take ownership of a `Vec` without copying its contents.
    pub fn from_vec(v: Vec<u8>) -> Bytes {
        let len = v.len();
        Bytes { buf: Arc::new(v), off: 0, len }
    }

    /// Copy a slice into a fresh allocation (the non-zero-copy ingress).
    pub fn copy_from_slice(s: &[u8]) -> Bytes {
        Bytes::from_vec(s.to_vec())
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn as_slice(&self) -> &[u8] {
        &self.buf[self.off..self.off + self.len]
    }

    /// A sub-view over `range` (relative to this view) sharing the same
    /// backing allocation — a refcount bump, no copy.
    ///
    /// Panics if the range is out of bounds, mirroring slice indexing.
    pub fn slice(&self, range: Range<usize>) -> Bytes {
        assert!(
            range.start <= range.end && range.end <= self.len,
            "slice {range:?} out of range for Bytes of len {}",
            self.len
        );
        Bytes {
            buf: Arc::clone(&self.buf),
            off: self.off + range.start,
            len: range.end - range.start,
        }
    }

    /// Whether two views share the same backing allocation.  This is the
    /// observable "no deep copy happened" property the store tests assert.
    pub fn shares_allocation(&self, other: &Bytes) -> bool {
        Arc::ptr_eq(&self.buf, &other.buf)
    }

    /// Copy the viewed bytes out into an owned `Vec`.
    pub fn to_vec(&self) -> Vec<u8> {
        self.as_slice().to_vec()
    }

    /// Recover the backing `Vec` without copying, if this is the only
    /// handle and the view covers the whole allocation; otherwise hand the
    /// view back unchanged.  Lets a frame buffer speculatively handed to
    /// the zero-copy decode path be reclaimed for reuse when nothing
    /// retained it (see `db::server`).
    pub fn try_unwrap_vec(self) -> std::result::Result<Vec<u8>, Bytes> {
        let (off, len) = (self.off, self.len);
        match Arc::try_unwrap(self.buf) {
            Ok(v) if off == 0 && len == v.len() => Ok(v),
            Ok(v) => Err(Bytes { buf: Arc::new(v), off, len }),
            Err(buf) => Err(Bytes { buf, off, len }),
        }
    }
}

impl Default for Bytes {
    fn default() -> Bytes {
        Bytes::new()
    }
}

impl Deref for Bytes {
    type Target = [u8];

    fn deref(&self) -> &[u8] {
        self.as_slice()
    }
}

impl From<Vec<u8>> for Bytes {
    fn from(v: Vec<u8>) -> Bytes {
        Bytes::from_vec(v)
    }
}

impl From<&[u8]> for Bytes {
    fn from(s: &[u8]) -> Bytes {
        Bytes::copy_from_slice(s)
    }
}

impl PartialEq for Bytes {
    fn eq(&self, other: &Bytes) -> bool {
        self.as_slice() == other.as_slice()
    }
}

impl Eq for Bytes {}

impl PartialEq<[u8]> for Bytes {
    fn eq(&self, other: &[u8]) -> bool {
        self.as_slice() == other
    }
}

impl PartialEq<Vec<u8>> for Bytes {
    fn eq(&self, other: &Vec<u8>) -> bool {
        self.as_slice() == &other[..]
    }
}

impl fmt::Debug for Bytes {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // Payloads run to tens of MB; show shape not contents.
        write!(f, "Bytes({} bytes @ +{})", self.len, self.off)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn clone_is_refcount_not_copy() {
        let a = Bytes::from_vec(vec![1, 2, 3, 4]);
        let b = a.clone();
        assert!(a.shares_allocation(&b));
        assert_eq!(a.as_ptr(), b.as_ptr());
        assert_eq!(a, b);
    }

    #[test]
    fn slice_views_share_and_window() {
        let a = Bytes::from_vec((0..10).collect());
        let mid = a.slice(2..7);
        assert_eq!(&mid[..], &[2, 3, 4, 5, 6]);
        assert!(mid.shares_allocation(&a));
        let inner = mid.slice(1..3);
        assert_eq!(&inner[..], &[3, 4]);
        assert!(inner.shares_allocation(&a));
        assert_eq!(a.slice(0..0).len(), 0);
    }

    #[test]
    #[should_panic]
    fn out_of_range_slice_panics() {
        Bytes::from_vec(vec![0; 4]).slice(2..6);
    }

    #[test]
    fn view_outlives_other_handles() {
        let v = Bytes::from_vec(vec![7; 32]);
        let view = v.slice(8..16);
        drop(v);
        assert_eq!(&view[..], &[7; 8]);
    }

    #[test]
    fn try_unwrap_vec_requires_exclusive_full_view() {
        // Sole handle over the whole allocation: recovered without copy.
        let v = Bytes::from_vec(vec![1, 2, 3]);
        assert_eq!(v.try_unwrap_vec().unwrap(), vec![1, 2, 3]);
        // A second handle blocks recovery; the view survives intact.
        let a = Bytes::from_vec(vec![4, 5]);
        let b = a.clone();
        let a = a.try_unwrap_vec().unwrap_err();
        assert_eq!(a, b);
        drop(b);
        // A partial view never yields the full buffer.
        let part = Bytes::from_vec(vec![6, 7, 8]).slice(1..3);
        let back = part.try_unwrap_vec().unwrap_err();
        assert_eq!(&back[..], &[7, 8]);
    }

    #[test]
    fn equality_is_by_content() {
        let a = Bytes::from_vec(vec![1, 2, 3]);
        let b = Bytes::copy_from_slice(&[1, 2, 3]);
        assert!(!a.shares_allocation(&b));
        assert_eq!(a, b);
        assert_eq!(a, vec![1, 2, 3]);
    }
}
