//! Tiny CLI argument parser (offline substitute for `clap`): subcommand plus
//! `--flag value` / `--flag=value` / boolean `--flag` options.

use std::collections::BTreeMap;

use crate::error::{Error, Result};

/// Parsed command line: `situ <command> [--k v]... [positional]...`
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub command: Option<String>,
    pub flags: BTreeMap<String, String>,
    pub positional: Vec<String>,
}

impl Args {
    /// Parse from an iterator of raw arguments (without argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Result<Args> {
        let mut out = Args::default();
        let mut it = raw.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(body) = a.strip_prefix("--") {
                if body.is_empty() {
                    return Err(Error::Invalid("bare '--' not supported".into()));
                }
                if let Some((k, v)) = body.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else {
                    // `--flag value` unless the next token is itself a flag
                    // (then it's a boolean).
                    let takes_value = it
                        .peek()
                        .map(|n| !n.starts_with("--"))
                        .unwrap_or(false);
                    if takes_value {
                        out.flags.insert(body.to_string(), it.next().unwrap());
                    } else {
                        out.flags.insert(body.to_string(), "true".to_string());
                    }
                }
            } else if out.command.is_none() {
                out.command = Some(a);
            } else {
                out.positional.push(a);
            }
        }
        Ok(out)
    }

    pub fn from_env() -> Result<Args> {
        Args::parse(std::env::args().skip(1))
    }

    pub fn str_opt(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.str_opt(key).unwrap_or(default).to_string()
    }

    pub fn usize_or(&self, key: &str, default: usize) -> Result<usize> {
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| Error::Invalid(format!("--{key} expects an integer, got '{v}'"))),
        }
    }

    pub fn f64_or(&self, key: &str, default: f64) -> Result<f64> {
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| Error::Invalid(format!("--{key} expects a number, got '{v}'"))),
        }
    }

    pub fn bool(&self, key: &str) -> bool {
        matches!(self.str_opt(key), Some("true") | Some("1") | Some("yes"))
    }

    /// Comma-separated list of usizes: `--sizes 1,4,16`.
    pub fn usize_list_or(&self, key: &str, default: &[usize]) -> Result<Vec<usize>> {
        match self.flags.get(key) {
            None => Ok(default.to_vec()),
            Some(v) => v
                .split(',')
                .filter(|s| !s.is_empty())
                .map(|s| {
                    s.trim()
                        .parse()
                        .map_err(|_| Error::Invalid(format!("--{key}: bad integer '{s}'")))
                })
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|x| x.to_string())).unwrap()
    }

    #[test]
    fn subcommand_and_flags() {
        let a = parse("serve --port 7700 --engine keydb --verbose");
        assert_eq!(a.command.as_deref(), Some("serve"));
        assert_eq!(a.str_opt("port"), Some("7700"));
        assert_eq!(a.str_opt("engine"), Some("keydb"));
        assert!(a.bool("verbose"));
    }

    #[test]
    fn equals_form() {
        let a = parse("bench --nodes=16 --sizes=1,4,16");
        assert_eq!(a.usize_or("nodes", 0).unwrap(), 16);
        assert_eq!(a.usize_list_or("sizes", &[]).unwrap(), vec![1, 4, 16]);
    }

    #[test]
    fn positional() {
        let a = parse("run file1 file2");
        assert_eq!(a.positional, vec!["file1", "file2"]);
    }

    #[test]
    fn defaults_and_errors() {
        let a = parse("x --n abc");
        assert_eq!(a.usize_or("m", 7).unwrap(), 7);
        assert!(a.usize_or("n", 0).is_err());
    }
}
