//! Deterministic fault injection for the transport layer.
//!
//! Chaos testing a distributed store is only useful if the failures are
//! *reproducible*: a run that flakes once a week under real `kill -9`
//! proves nothing in CI.  [`FaultPlan`] is a seeded description of
//! transport misbehaviour — sever a connection, delay an I/O op, truncate
//! a write mid-frame — and [`FaultStream`] is the shim that applies it
//! around a real socket.  Both the server accept path
//! (`ServerConfig::fault`) and the client connect path
//! (`ClusterConfig::faults`) can wear the shim, so every failure mode the
//! chaos battery exercises is a pure function of the seed plus the frame
//! traffic, not of wall-clock timing.
//!
//! Determinism discipline: decisions are drawn from one
//! [`crate::util::rng::Rng`] stream per connection (connection `k` of a
//! plan is seeded from `(plan seed, k)`), and a decision is only consumed
//! by an op that actually moved bytes — idle read polls (`WouldBlock`)
//! draw nothing, so the server's read-timeout cadence cannot perturb the
//! sequence.  Writes draw *before* attempting the kernel write (a truncate
//! must fire on the attempt), so a nonblocking `WouldBlock` write parks its
//! decision and the retry re-uses it instead of drawing again — the event
//! loop's retry cadence cannot perturb the sequence either.  The same seed
//! therefore yields the same *decision sequence* per connection; what
//! varies run-to-run is only how the OS chunks the byte stream across
//! reads.
//!
//! Fault vocabulary:
//! - **sever** — the op fails with `ConnectionReset` and every later op on
//!   the connection fails too (a peer death as the kernel reports it);
//! - **delay** — the op completes after an injected sleep (congestion,
//!   scheduling jitter);
//! - **truncate** — a write delivers only a prefix of the buffer and then
//!   severs, leaving the peer holding a torn frame (a crash mid-send);
//! - **kill switch** — [`FaultPlan::kill`] fails every op on every
//!   connection of the plan at once (whole-process death as seen from the
//!   other side), until [`FaultPlan::revive`].

use std::io::{self, Read, Write};
use std::net::TcpStream;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::util::rng::Rng;

/// What a [`FaultPlan`] does and how often.  Probabilities are per
/// byte-moving I/O op; scripted fields fire at exact op counts (useful for
/// pinning a failure to a precise protocol position).
#[derive(Debug, Clone)]
pub struct FaultConfig {
    /// Seed for the per-connection decision streams.
    pub seed: u64,
    /// Probability an op severs the connection.
    pub sever_p: f64,
    /// Probability a write is truncated mid-buffer, then severed.
    pub truncate_p: f64,
    /// Probability an op is delayed by `delay` before completing.
    pub delay_p: f64,
    pub delay: Duration,
    /// Scripted: sever every connection after this many byte-moving ops.
    pub sever_after_ops: Option<u64>,
    /// Scripted: truncate the Nth write (1-based, per connection) to half
    /// its buffer, then sever.
    pub truncate_write_op: Option<u64>,
}

impl Default for FaultConfig {
    fn default() -> Self {
        FaultConfig {
            seed: 0,
            sever_p: 0.0,
            truncate_p: 0.0,
            delay_p: 0.0,
            delay: Duration::from_micros(500),
            sever_after_ops: None,
            truncate_write_op: None,
        }
    }
}

impl FaultConfig {
    /// A mixed probabilistic plan scaled by `intensity` (1.0 ≈ a failure
    /// every few hundred ops — rough weather, not a dead shard).  This is
    /// what `--chaos-seed`/`--chaos-intensity` construct.
    pub fn with_intensity(seed: u64, intensity: f64) -> FaultConfig {
        FaultConfig {
            seed,
            sever_p: 0.002 * intensity,
            truncate_p: 0.001 * intensity,
            delay_p: 0.02 * intensity,
            delay: Duration::from_micros(500),
            ..FaultConfig::default()
        }
    }
}

/// Totals of what a plan actually injected (for reports and assertions).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct FaultCounters {
    pub severed_conns: u64,
    pub delayed_ops: u64,
    pub truncated_writes: u64,
}

/// A shared, seeded fault schedule.  One plan typically covers one server
/// instance (or one client); each accepted/established connection derives
/// its own deterministic decision stream via [`FaultPlan::connection`].
#[derive(Debug)]
pub struct FaultPlan {
    cfg: FaultConfig,
    conn_seq: AtomicU64,
    killed: AtomicBool,
    severed_conns: AtomicU64,
    delayed_ops: AtomicU64,
    truncated_writes: AtomicU64,
}

impl FaultPlan {
    pub fn new(cfg: FaultConfig) -> FaultPlan {
        FaultPlan {
            cfg,
            conn_seq: AtomicU64::new(0),
            killed: AtomicBool::new(false),
            severed_conns: AtomicU64::new(0),
            delayed_ops: AtomicU64::new(0),
            truncated_writes: AtomicU64::new(0),
        }
    }

    pub fn config(&self) -> &FaultConfig {
        &self.cfg
    }

    /// Fault state for the next connection: connection `k` of a plan with
    /// seed `s` always draws the same decision sequence, independent of
    /// every other connection.
    pub fn connection(self: &Arc<FaultPlan>) -> Arc<ConnFaults> {
        let k = self.conn_seq.fetch_add(1, Ordering::Relaxed);
        // Splitmix-style stir so (seed, k) and (seed, k+1) are unrelated.
        let seed = self
            .cfg
            .seed
            .wrapping_add(k.wrapping_mul(0x9e37_79b9_7f4a_7c15))
            .wrapping_mul(0xbf58_476d_1ce4_e5b9);
        Arc::new(ConnFaults {
            plan: Arc::clone(self),
            inner: Mutex::new(ConnState {
                rng: Rng::new(seed),
                severed: false,
                ops: 0,
                write_ops: 0,
                pending_write: None,
            }),
        })
    }

    /// Fail every op on every connection of this plan from now on — the
    /// whole process died, as seen from the other end of its sockets.
    pub fn kill(&self) {
        self.killed.store(true, Ordering::Relaxed);
    }

    /// Undo [`FaultPlan::kill`] (the process came back).
    pub fn revive(&self) {
        self.killed.store(false, Ordering::Relaxed);
    }

    pub fn counters(&self) -> FaultCounters {
        FaultCounters {
            severed_conns: self.severed_conns.load(Ordering::Relaxed),
            delayed_ops: self.delayed_ops.load(Ordering::Relaxed),
            truncated_writes: self.truncated_writes.load(Ordering::Relaxed),
        }
    }
}

/// One connection's slice of a [`FaultPlan`]: its own decision stream plus
/// a sticky severed flag shared by the read and write halves of the socket.
#[derive(Debug)]
pub struct ConnFaults {
    plan: Arc<FaultPlan>,
    inner: Mutex<ConnState>,
}

#[derive(Debug)]
struct ConnState {
    rng: Rng,
    severed: bool,
    /// Byte-moving ops decided so far (reads that returned data + writes).
    ops: u64,
    write_ops: u64,
    /// Decision drawn for a write the kernel then refused (`WouldBlock`);
    /// the retry consumes this instead of drawing again.
    pending_write: Option<FaultDecision>,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum FaultDecision {
    Pass,
    Delay(Duration),
    Sever,
    /// Write only this prefix of the buffer, then sever.
    Truncate(usize),
}

fn sever_err(msg: &str) -> io::Error {
    io::Error::new(io::ErrorKind::ConnectionReset, msg.to_string())
}

impl ConnFaults {
    fn check_severed(&self) -> io::Result<()> {
        if self.plan.killed.load(Ordering::Relaxed) {
            return Err(sever_err("injected fault: plan killed"));
        }
        if self.inner.lock().expect("fault state lock").severed {
            return Err(sever_err("injected fault: connection severed"));
        }
        Ok(())
    }

    /// Draw the next decision for a byte-moving op.  Exactly one RNG draw
    /// per call, regardless of which branch fires, so the decision index
    /// equals the op index.
    fn decide(&self, is_write: bool, len: usize) -> FaultDecision {
        let cfg = &self.plan.cfg;
        let mut st = self.inner.lock().expect("fault state lock");
        st.ops += 1;
        if is_write {
            st.write_ops += 1;
        }
        // Scripted faults take precedence (they exist to pin a failure to
        // an exact protocol position) and consume no randomness.
        if is_write && cfg.truncate_write_op == Some(st.write_ops) {
            st.severed = true;
            self.plan.truncated_writes.fetch_add(1, Ordering::Relaxed);
            self.plan.severed_conns.fetch_add(1, Ordering::Relaxed);
            return FaultDecision::Truncate(len / 2);
        }
        if let Some(n) = cfg.sever_after_ops {
            if st.ops > n {
                st.severed = true;
                self.plan.severed_conns.fetch_add(1, Ordering::Relaxed);
                return FaultDecision::Sever;
            }
        }
        let x = st.rng.f64();
        if x < cfg.sever_p {
            st.severed = true;
            self.plan.severed_conns.fetch_add(1, Ordering::Relaxed);
            FaultDecision::Sever
        } else if x < cfg.sever_p + cfg.truncate_p {
            if is_write {
                st.severed = true;
                self.plan.truncated_writes.fetch_add(1, Ordering::Relaxed);
                self.plan.severed_conns.fetch_add(1, Ordering::Relaxed);
                FaultDecision::Truncate(len / 2)
            } else {
                // Reads have no truncation analogue; the band passes so the
                // draw count stays aligned with the op count.
                FaultDecision::Pass
            }
        } else if x < cfg.sever_p + cfg.truncate_p + cfg.delay_p {
            self.plan.delayed_ops.fetch_add(1, Ordering::Relaxed);
            FaultDecision::Delay(cfg.delay)
        } else {
            FaultDecision::Pass
        }
    }

    fn take_pending_write(&self) -> Option<FaultDecision> {
        self.inner.lock().expect("fault state lock").pending_write.take()
    }

    fn park_pending_write(&self, d: FaultDecision) {
        self.inner.lock().expect("fault state lock").pending_write = Some(d);
    }
}

/// A stream with an optional fault schedule in front of it.  With
/// `faults: None` it is a transparent pass-through (the production
/// configuration — one branch per op).
#[derive(Debug)]
pub struct FaultStream<S = TcpStream> {
    inner: S,
    faults: Option<Arc<ConnFaults>>,
}

impl<S> FaultStream<S> {
    pub fn over(inner: S, faults: Option<Arc<ConnFaults>>) -> FaultStream<S> {
        FaultStream { inner, faults }
    }

    pub fn get_ref(&self) -> &S {
        &self.inner
    }
}

impl FaultStream<TcpStream> {
    /// Clone the socket; the clone shares this connection's fault state
    /// (reader and writer halves sever together, like a real socket).
    pub fn try_clone(&self) -> io::Result<FaultStream<TcpStream>> {
        Ok(FaultStream {
            inner: self.inner.try_clone()?,
            faults: self.faults.clone(),
        })
    }

    pub fn set_read_timeout(&self, d: Option<Duration>) -> io::Result<()> {
        self.inner.set_read_timeout(d)
    }

    pub fn set_write_timeout(&self, d: Option<Duration>) -> io::Result<()> {
        self.inner.set_write_timeout(d)
    }
}

impl<S: Read> Read for FaultStream<S> {
    fn read(&mut self, buf: &mut [u8]) -> io::Result<usize> {
        let Some(f) = &self.faults else {
            return self.inner.read(buf);
        };
        f.check_severed()?;
        // Decide only after bytes actually arrive: idle polls (WouldBlock /
        // TimedOut) and clean EOF consume no decision, so the read-timeout
        // cadence cannot perturb the deterministic sequence.
        let n = self.inner.read(buf)?;
        if n == 0 {
            return Ok(0);
        }
        match f.decide(false, n) {
            FaultDecision::Pass => Ok(n),
            FaultDecision::Delay(d) => {
                std::thread::sleep(d);
                Ok(n)
            }
            // A severed read drops the bytes it consumed — the connection
            // is dead either way.
            FaultDecision::Sever | FaultDecision::Truncate(_) => {
                Err(sever_err("injected fault: read severed"))
            }
        }
    }
}

impl<S: Write> Write for FaultStream<S> {
    fn write(&mut self, buf: &[u8]) -> io::Result<usize> {
        let Some(f) = &self.faults else {
            return self.inner.write(buf);
        };
        f.check_severed()?;
        if buf.is_empty() {
            return self.inner.write(buf);
        }
        // A decision parked by an earlier `WouldBlock` retry is consumed
        // first; otherwise draw.  Either way exactly one decision per write
        // that the kernel eventually accepts.
        let decision = f.take_pending_write().unwrap_or_else(|| f.decide(true, buf.len()));
        match decision {
            FaultDecision::Pass => match self.inner.write(buf) {
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
                    f.park_pending_write(FaultDecision::Pass);
                    Err(e)
                }
                r => r,
            },
            FaultDecision::Delay(d) => {
                std::thread::sleep(d);
                match self.inner.write(buf) {
                    Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
                        // The delay is already paid; the retry passes clean.
                        f.park_pending_write(FaultDecision::Pass);
                        Err(e)
                    }
                    r => r,
                }
            }
            FaultDecision::Sever => Err(sever_err("injected fault: write severed")),
            FaultDecision::Truncate(n) => {
                // Deliver a torn prefix so the peer sees a frame die
                // mid-body, then report the connection broken.
                if n > 0 {
                    let _ = self.inner.write(&buf[..n]);
                }
                let _ = self.inner.flush();
                Err(io::Error::new(
                    io::ErrorKind::BrokenPipe,
                    "injected fault: write truncated mid-frame",
                ))
            }
        }
    }

    fn flush(&mut self) -> io::Result<()> {
        self.inner.flush()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mixed_cfg(seed: u64) -> FaultConfig {
        FaultConfig {
            seed,
            sever_p: 0.05,
            truncate_p: 0.05,
            delay_p: 0.4,
            delay: Duration::from_micros(1),
            ..FaultConfig::default()
        }
    }

    /// Decision sequence fingerprint for one fresh connection of `plan`.
    fn fingerprint(plan: &Arc<FaultPlan>, n: usize) -> Vec<u8> {
        let c = plan.connection();
        (0..n)
            .map(|i| match c.decide(i % 2 == 0, 100) {
                FaultDecision::Pass => 0,
                FaultDecision::Delay(_) => 1,
                FaultDecision::Sever => 2,
                FaultDecision::Truncate(_) => 3,
            })
            .collect()
    }

    #[test]
    fn same_seed_same_decisions() {
        let a = Arc::new(FaultPlan::new(mixed_cfg(42)));
        let b = Arc::new(FaultPlan::new(mixed_cfg(42)));
        assert_eq!(fingerprint(&a, 256), fingerprint(&b, 256));
    }

    #[test]
    fn connections_and_seeds_draw_distinct_streams() {
        let plan = Arc::new(FaultPlan::new(mixed_cfg(42)));
        let c0 = fingerprint(&plan, 256);
        let c1 = fingerprint(&plan, 256);
        assert_ne!(c0, c1, "per-connection streams independent");
        let other = Arc::new(FaultPlan::new(mixed_cfg(43)));
        assert_ne!(c0, fingerprint(&other, 256), "seed changes the schedule");
    }

    #[test]
    fn passthrough_when_no_faults() {
        let mut s = FaultStream::over(Vec::<u8>::new(), None);
        s.write_all(b"hello").unwrap();
        assert_eq!(s.get_ref(), b"hello");
        let mut r = FaultStream::over(&b"abc"[..], None);
        let mut out = Vec::new();
        r.read_to_end(&mut out).unwrap();
        assert_eq!(out, b"abc");
    }

    #[test]
    fn scripted_truncate_fires_at_exact_write_and_stays_severed() {
        let plan = Arc::new(FaultPlan::new(FaultConfig {
            seed: 7,
            truncate_write_op: Some(3),
            ..FaultConfig::default()
        }));
        let mut s = FaultStream::over(Vec::<u8>::new(), Some(plan.connection()));
        s.write_all(b"aaaa").unwrap();
        s.write_all(b"bbbb").unwrap();
        let err = s.write_all(b"cccc").unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::BrokenPipe);
        // Half the third buffer landed before the sever.
        assert_eq!(s.get_ref().as_slice(), b"aaaabbbbcc");
        // Sticky: both halves of the connection are dead now.
        let err = s.write_all(b"dddd").unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::ConnectionReset);
        assert_eq!(
            plan.counters(),
            FaultCounters { severed_conns: 1, delayed_ops: 0, truncated_writes: 1 }
        );
    }

    #[test]
    fn scripted_sever_after_ops() {
        let plan = Arc::new(FaultPlan::new(FaultConfig {
            seed: 7,
            sever_after_ops: Some(2),
            ..FaultConfig::default()
        }));
        let conn = plan.connection();
        let mut s = FaultStream::over(Vec::<u8>::new(), Some(conn));
        s.write_all(b"a").unwrap();
        s.write_all(b"b").unwrap();
        let err = s.write_all(b"c").unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::ConnectionReset);
        assert_eq!(plan.counters().severed_conns, 1);
    }

    #[test]
    fn kill_switch_fails_every_connection_until_revive() {
        let plan = Arc::new(FaultPlan::new(FaultConfig::default()));
        let mut a = FaultStream::over(Vec::<u8>::new(), Some(plan.connection()));
        let mut b = FaultStream::over(Vec::<u8>::new(), Some(plan.connection()));
        a.write_all(b"x").unwrap();
        plan.kill();
        assert_eq!(a.write_all(b"y").unwrap_err().kind(), io::ErrorKind::ConnectionReset);
        assert_eq!(b.write_all(b"y").unwrap_err().kind(), io::ErrorKind::ConnectionReset);
        plan.revive();
        a.write_all(b"z").unwrap();
        assert_eq!(a.get_ref().as_slice(), b"xz");
    }

    #[test]
    fn reads_only_consume_decisions_when_bytes_move() {
        // A plan that severs on the very first decided op: an empty source
        // (EOF) must NOT consume it, a byte-yielding read must.
        let cfg = FaultConfig { seed: 1, sever_after_ops: Some(0), ..FaultConfig::default() };
        let plan = Arc::new(FaultPlan::new(cfg));
        let mut eof = FaultStream::over(&b""[..], Some(plan.connection()));
        let mut buf = [0u8; 8];
        assert_eq!(eof.read(&mut buf).unwrap(), 0, "EOF passes through undecided");
        let mut live = FaultStream::over(&b"data"[..], Some(plan.connection()));
        assert_eq!(live.read(&mut buf).unwrap_err().kind(), io::ErrorKind::ConnectionReset);
    }

    /// Sink that refuses the first write with `WouldBlock`, like a full
    /// nonblocking socket buffer, then accepts everything.
    struct FullOnce {
        out: Vec<u8>,
        refusals_left: usize,
    }

    impl Write for FullOnce {
        fn write(&mut self, buf: &[u8]) -> io::Result<usize> {
            if self.refusals_left > 0 {
                self.refusals_left -= 1;
                return Err(io::Error::new(io::ErrorKind::WouldBlock, "kernel buffer full"));
            }
            self.out.extend_from_slice(buf);
            Ok(buf.len())
        }

        fn flush(&mut self) -> io::Result<()> {
            Ok(())
        }
    }

    #[test]
    fn wouldblock_write_retry_reuses_its_decision() {
        // Plan severs on decided op 2.  The first write draws op 1 (Pass),
        // gets WouldBlock, and retries: the retry must re-use that parked
        // decision, so the *second* buffer — not the retry — draws the
        // severing op 2.
        let plan = Arc::new(FaultPlan::new(FaultConfig {
            seed: 7,
            sever_after_ops: Some(1),
            ..FaultConfig::default()
        }));
        let sink = FullOnce { out: Vec::new(), refusals_left: 1 };
        let mut s = FaultStream::over(sink, Some(plan.connection()));
        assert_eq!(s.write(b"aaaa").unwrap_err().kind(), io::ErrorKind::WouldBlock);
        assert_eq!(s.write(b"aaaa").unwrap(), 4, "retry passes on the parked decision");
        assert_eq!(s.write(b"bbbb").unwrap_err().kind(), io::ErrorKind::ConnectionReset);
        assert_eq!(s.get_ref().out, b"aaaa");
        assert_eq!(plan.counters().severed_conns, 1);
    }

    #[test]
    fn intensity_scales_probabilities() {
        let c = FaultConfig::with_intensity(5, 2.0);
        assert_eq!(c.seed, 5);
        assert!(c.sever_p > 0.0 && c.delay_p > c.sever_p);
        let gentle = FaultConfig::with_intensity(5, 0.5);
        assert!(gentle.sever_p < c.sever_p);
    }
}
