//! Human-readable formatting helpers shared by the CLI, telemetry tables and
//! bench harnesses.

/// `1536` -> `"1.5 KB"`, `268435456` -> `"256.0 MB"`.
pub fn bytes(n: u64) -> String {
    const UNITS: [&str; 5] = ["B", "KB", "MB", "GB", "TB"];
    let mut v = n as f64;
    let mut u = 0;
    while v >= 1024.0 && u + 1 < UNITS.len() {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{n} B")
    } else {
        format!("{v:.1} {}", UNITS[u])
    }
}

/// Seconds -> adaptive `"412 µs"` / `"1.23 ms"` / `"4.5 s"`.
pub fn duration(secs: f64) -> String {
    if secs < 0.0 {
        return format!("-{}", duration(-secs));
    }
    if secs < 1e-6 {
        format!("{:.0} ns", secs * 1e9)
    } else if secs < 1e-3 {
        format!("{:.1} µs", secs * 1e6)
    } else if secs < 1.0 {
        format!("{:.2} ms", secs * 1e3)
    } else if secs < 120.0 {
        format!("{secs:.2} s")
    } else {
        format!("{:.1} min", secs / 60.0)
    }
}

/// Rate in bytes/sec -> `"1.2 GB/s"`.
pub fn throughput(bytes_per_sec: f64) -> String {
    format!("{}/s", bytes(bytes_per_sec as u64))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bytes_units() {
        assert_eq!(bytes(12), "12 B");
        assert_eq!(bytes(1536), "1.5 KB");
        assert_eq!(bytes(256 * 1024 * 1024), "256.0 MB");
    }

    #[test]
    fn duration_units() {
        assert_eq!(duration(0.000000412), "412 ns");
        assert_eq!(duration(0.000412), "412.0 µs");
        assert_eq!(duration(0.00123), "1.23 ms");
        assert_eq!(duration(4.5), "4.50 s");
        assert_eq!(duration(150.0), "2.5 min");
    }
}
