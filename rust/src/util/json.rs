//! Minimal JSON parser/writer (offline substitute for `serde_json`).
//!
//! Supports the full JSON grammar; numbers are kept as `f64` (the manifest
//! never exceeds 2^53).  Object key order is preserved (`Vec` of pairs) so
//! emitted documents are deterministic.

use std::fmt;

use crate::error::{Error, Result};

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    /// Parse a JSON document from text.
    pub fn parse(s: &str) -> Result<Json> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(Error::Parse(format!("trailing data at byte {}", p.i)));
        }
        Ok(v)
    }

    /// Member lookup on objects; `None` for other variants / missing keys.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// Member lookup that errors with the key name (manifest parsing).
    pub fn req(&self, key: &str) -> Result<&Json> {
        self.get(key)
            .ok_or_else(|| Error::Parse(format!("missing key '{key}'")))
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|n| n as i64)
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().filter(|n| *n >= 0.0).map(|n| n as usize)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&[(String, Json)]> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// `[1,2,3]` -> `vec![1,2,3]`, erroring on non-numeric members.
    pub fn usize_array(&self) -> Result<Vec<usize>> {
        self.as_arr()
            .ok_or_else(|| Error::Parse("expected array".into()))?
            .iter()
            .map(|v| {
                v.as_usize()
                    .ok_or_else(|| Error::Parse("expected unsigned int".into()))
            })
            .collect()
    }

    /// Serialize compactly (no whitespace).
    pub fn to_string_compact(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.to_string_compact())
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b
            .get(self.i)
            .copied()
            .ok_or_else(|| Error::Parse("unexpected end of input".into()))
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            return Err(Error::Parse(format!(
                "expected '{}' at byte {}, found '{}'",
                c as char, self.i, self.b[self.i] as char
            )));
        }
        self.i += 1;
        Ok(())
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(Error::Parse(format!("bad literal at byte {}", self.i)))
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut m = Vec::new();
        self.ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let v = self.value()?;
            m.push((k, v));
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                c => {
                    return Err(Error::Parse(format!(
                        "expected ',' or '}}' at byte {}, found '{}'",
                        self.i, c as char
                    )))
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut a = Vec::new();
        self.ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            self.ws();
            a.push(self.value()?);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(a));
                }
                c => {
                    return Err(Error::Parse(format!(
                        "expected ',' or ']' at byte {}, found '{}'",
                        self.i, c as char
                    )))
                }
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{0008}'),
                        b'f' => s.push('\u{000c}'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                return Err(Error::Parse("truncated \\u escape".into()));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])
                                .map_err(|_| Error::Parse("bad \\u escape".into()))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| Error::Parse("bad \\u escape".into()))?;
                            self.i += 4;
                            // Surrogate pairs: decode the low half if present.
                            let ch = if (0xd800..0xdc00).contains(&cp) {
                                if self.b.get(self.i) == Some(&b'\\')
                                    && self.b.get(self.i + 1) == Some(&b'u')
                                    && self.i + 6 <= self.b.len()
                                {
                                    let hex2 =
                                        std::str::from_utf8(&self.b[self.i + 2..self.i + 6])
                                            .map_err(|_| Error::Parse("bad surrogate".into()))?;
                                    let lo = u32::from_str_radix(hex2, 16)
                                        .map_err(|_| Error::Parse("bad surrogate".into()))?;
                                    self.i += 6;
                                    0x10000 + ((cp - 0xd800) << 10) + (lo - 0xdc00)
                                } else {
                                    return Err(Error::Parse("lone surrogate".into()));
                                }
                            } else {
                                cp
                            };
                            s.push(
                                char::from_u32(ch)
                                    .ok_or_else(|| Error::Parse("bad codepoint".into()))?,
                            );
                        }
                        _ => return Err(Error::Parse(format!("bad escape '\\{}'", e as char))),
                    }
                }
                c if c < 0x20 => return Err(Error::Parse("control char in string".into())),
                c => {
                    // Re-assemble multi-byte UTF-8 sequences.
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let start = self.i - 1;
                        let len = if c >= 0xf0 {
                            4
                        } else if c >= 0xe0 {
                            3
                        } else {
                            2
                        };
                        if start + len > self.b.len() {
                            return Err(Error::Parse("truncated utf-8".into()));
                        }
                        let chunk = std::str::from_utf8(&self.b[start..start + len])
                            .map_err(|_| Error::Parse("bad utf-8".into()))?;
                        s.push_str(chunk);
                        self.i = start + len;
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        if self.peek()? == b'-' {
            self.i += 1;
        }
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-')
        {
            self.i += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.i])
            .map_err(|_| Error::Parse("bad number".into()))?;
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| Error::Parse(format!("bad number '{text}'")))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse(" false ").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-1.5e3").unwrap(), Json::Num(-1500.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, {"b": null}], "c": "x\ny"}"#).unwrap();
        assert_eq!(j.get("c").unwrap().as_str().unwrap(), "x\ny");
        let a = j.get("a").unwrap().as_arr().unwrap();
        assert_eq!(a[0], Json::Num(1.0));
        assert_eq!(a[1].get("b").unwrap(), &Json::Null);
    }

    #[test]
    fn parse_escapes() {
        let j = Json::parse(r#""A\t\"\\é""#).unwrap();
        assert_eq!(j.as_str().unwrap(), "A\t\"\\é");
    }

    #[test]
    fn parse_surrogate_pair() {
        let j = Json::parse(r#""😀""#).unwrap();
        assert_eq!(j.as_str().unwrap(), "😀");
    }

    #[test]
    fn parse_utf8_passthrough() {
        let j = Json::parse("\"héllo wörld ✓\"").unwrap();
        assert_eq!(j.as_str().unwrap(), "héllo wörld ✓");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("").is_err());
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"abc").is_err());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"model":{"latent":100,"lr":0.0001},"names":["a","b"],"ok":true,"x":null}"#;
        let j = Json::parse(src).unwrap();
        let out = j.to_string_compact();
        assert_eq!(Json::parse(&out).unwrap(), j);
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(vec![]));
        assert_eq!(Json::parse("{ }").unwrap(), Json::Obj(vec![]));
    }

    #[test]
    fn usize_array() {
        let j = Json::parse("[4, 1024]").unwrap();
        assert_eq!(j.usize_array().unwrap(), vec![4, 1024]);
        assert!(Json::parse("[4, -1]").unwrap().usize_array().is_err());
    }

    #[test]
    fn integer_formatting_is_stable() {
        assert_eq!(Json::Num(100.0).to_string_compact(), "100");
        assert_eq!(Json::Num(0.5).to_string_compact(), "0.5");
    }
}
