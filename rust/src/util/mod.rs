//! Dependency-free building blocks: JSON, RNG, CLI parsing, property-test
//! harness, human formatting.  The build environment is offline, so the
//! substrates a crates.io project would pull in are implemented here.

pub mod bytes;
pub mod cli;
pub mod fault;
pub mod fmt;
pub mod json;
pub mod propcheck;
pub mod rng;
