//! Mini property-testing harness (offline substitute for `proptest`).
//!
//! A property is a closure taking a [`Gen`]; the harness runs it over many
//! seeded generators and, on failure, reports the seed so the case replays
//! deterministically:
//!
//! ```
//! use situ::util::propcheck::{check, Gen};
//! check("reverse twice is identity", 200, |g: &mut Gen| {
//!     let v: Vec<u32> = g.vec(0..=64, |g| g.u32());
//!     let mut w = v.clone();
//!     w.reverse();
//!     w.reverse();
//!     assert_eq!(v, w);
//! });
//! ```

use super::rng::Rng;

/// Value generator handed to each property-test case.
pub struct Gen {
    rng: Rng,
    pub seed: u64,
}

impl Gen {
    pub fn new(seed: u64) -> Self {
        Gen { rng: Rng::new(seed), seed }
    }

    pub fn u32(&mut self) -> u32 {
        self.rng.next_u64() as u32
    }

    pub fn u64(&mut self) -> u64 {
        self.rng.next_u64()
    }

    /// Uniform usize within an inclusive range.
    pub fn usize_in(&mut self, r: std::ops::RangeInclusive<usize>) -> usize {
        let (lo, hi) = (*r.start(), *r.end());
        lo + self.rng.below(hi - lo + 1)
    }

    pub fn f32(&mut self) -> f32 {
        self.rng.f32()
    }

    pub fn f64(&mut self) -> f64 {
        self.rng.f64()
    }

    /// Standard-normal f32 (tensor payloads).
    pub fn normal_f32(&mut self) -> f32 {
        self.rng.normal() as f32
    }

    pub fn bool(&mut self) -> bool {
        self.rng.bool(0.5)
    }

    /// Vector with length drawn from `len` and elements from `f`.
    pub fn vec<T>(
        &mut self,
        len: std::ops::RangeInclusive<usize>,
        mut f: impl FnMut(&mut Gen) -> T,
    ) -> Vec<T> {
        let n = self.usize_in(len);
        (0..n).map(|_| f(self)).collect()
    }

    /// ASCII identifier-ish string (keys).
    pub fn key(&mut self) -> String {
        const CH: &[u8] = b"abcdefghijklmnopqrstuvwxyz0123456789_.:{}";
        let n = self.usize_in(1..=32);
        (0..n)
            .map(|_| CH[self.rng.below(CH.len())] as char)
            .collect()
    }

    /// Pick one element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.rng.below(xs.len())]
    }

    pub fn rng(&mut self) -> &mut Rng {
        &mut self.rng
    }
}

/// Run `prop` across `cases` seeded generators; panic (with the seed) on the
/// first failing case.
pub fn check(name: &str, cases: u64, prop: impl Fn(&mut Gen) + std::panic::RefUnwindSafe) {
    // Base seed is fixed: failures reproduce without environment plumbing.
    for case in 0..cases {
        let seed = 0x5157_u64.wrapping_mul(0x9e3779b97f4a7c15).wrapping_add(case);
        let result = std::panic::catch_unwind(|| {
            let mut g = Gen::new(seed);
            prop(&mut g);
        });
        if let Err(e) = result {
            let msg = e
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!("property '{name}' failed on case {case} (seed {seed:#x}): {msg}");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        check("tautology", 50, |g| {
            let x = g.u32();
            assert_eq!(x, x);
        });
    }

    #[test]
    #[should_panic(expected = "property 'falsifiable' failed")]
    fn failing_property_reports_seed() {
        check("falsifiable", 50, |g| {
            assert!(g.usize_in(0..=9) < 9, "hit the 10%");
        });
    }

    #[test]
    fn gen_ranges() {
        let mut g = Gen::new(3);
        for _ in 0..1000 {
            let x = g.usize_in(5..=7);
            assert!((5..=7).contains(&x));
        }
        let v = g.vec(2..=4, |g| g.bool());
        assert!((2..=4).contains(&v.len()));
        let k = g.key();
        assert!(!k.is_empty() && k.len() <= 32);
    }
}
