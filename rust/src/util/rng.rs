//! Small deterministic PRNG (xoshiro256**) for workload generation, the DES
//! and the property-test harness.  Deterministic seeding keeps every bench
//! and test reproducible run-to-run.

/// xoshiro256** by Blackman & Vigna (public domain reference implementation).
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via splitmix64 so any u64 (including 0) yields a good state.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9e37_79b9_7f4a_7c15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
            z ^ (z >> 31)
        };
        Rng {
            s: [next(), next(), next(), next()],
        }
    }

    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in `[0, 1)`.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in `[0, n)`; `n` must be > 0.
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0, "Rng::below(0)");
        // Rejection-free multiply-shift (Lemire); bias is negligible for the
        // ranges used here but we keep the widening-multiply form anyway.
        let r = self.next_u64() as u128;
        ((r * n as u128) >> 64) as usize
    }

    /// Uniform in `[lo, hi)`.
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Exponential with mean `mean` (service/interarrival times in the DES).
    pub fn exp(&mut self, mean: f64) -> f64 {
        -mean * (1.0 - self.f64()).ln()
    }

    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// `k` distinct indices drawn from `[0, n)` (k <= n).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        self.shuffle(&mut idx);
        idx.truncate(k);
        idx
    }

    /// Vector of standard-normal f32s.
    pub fn normal_vec_f32(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| self.normal() as f32).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        assert_ne!(Rng::new(1).next_u64(), Rng::new(2).next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::new(9);
        let mut seen = [false; 10];
        for _ in 0..1_000 {
            seen[r.below(10)] = true;
        }
        assert!(seen.iter().all(|&s| s), "all buckets hit");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn exp_mean() {
        let mut r = Rng::new(13);
        let n = 50_000;
        let m = (0..n).map(|_| r.exp(2.5)).sum::<f64>() / n as f64;
        assert!((m - 2.5).abs() < 0.1, "mean {m}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(17);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(19);
        let s = r.sample_indices(50, 20);
        assert_eq!(s.len(), 20);
        let mut d = s.clone();
        d.sort_unstable();
        d.dedup();
        assert_eq!(d.len(), 20);
    }
}
