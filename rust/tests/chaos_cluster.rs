//! Chaos battery: replicated cluster writes, read failover, and the
//! deterministic fault-injection harness.
//!
//! The robustness claims of `client::ClusterClient` + `util::fault` are
//! earned here:
//!
//! * with `replicas ≥ 2`, killing any single shard mid-run — by the seeded
//!   kill switch, by in-process crash, or by a real `kill -9` on a `situ
//!   serve` child process — loses **zero** data replicated before the
//!   kill: every read comes back byte-exact through failover, and writes
//!   keep landing (degraded, with per-shard error reports);
//! * with `replicas = 1`, a dead shard produces clean, *bounded-time*
//!   transient errors — never a hang, never a panic — while keys on
//!   surviving shards stay fully served;
//! * a run under a seeded probabilistic fault plan (delays, severs,
//!   mid-frame write truncations) completes byte-exact once wrapped in the
//!   transient-I/O retry class;
//! * a connection severed between `begin_split_frame`/`end_split_frame`
//!   leaves the store untouched and the server serving;
//! * client sockets carry an I/O deadline, so a hung (never-replying)
//!   server surfaces as a retryable timeout within the deadline;
//! * `simulate_crash` (no clean-shutdown spill barrier) after an `info`
//!   durability barrier loses nothing from the cold tier on restart.
//!
//! Scale knobs mirror the stress suite: `SITU_CHAOS_STEPS` (default 10;
//! CI smoke uses 40) and `SITU_CHAOS_SEED` (default 7).

use std::io::{BufRead, BufReader, Write as _};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use situ::client::{
    tensor_key, Client, ClusterClient, ClusterConfig, DataStore, RetryClass, RetryPolicy,
};
use situ::db::{DbServer, Engine, RetentionConfig, ServerConfig, SpillConfig};
use situ::ml::DataLoader;
use situ::orchestrator::{backfill, reshard, BackfillConfig, ReshardConfig};
use situ::tensor::Tensor;
use situ::util::fault::{FaultConfig, FaultPlan};
use situ::Error;

fn chaos_steps() -> u64 {
    std::env::var("SITU_CHAOS_STEPS").ok().and_then(|s| s.parse().ok()).unwrap_or(10)
}

fn chaos_seed() -> u64 {
    std::env::var("SITU_CHAOS_SEED").ok().and_then(|s| s.parse().ok()).unwrap_or(7)
}

/// Short-teardown server config shared by the battery (the suite starts
/// and kills many servers; library-default timeouts would serialize it).
fn shard_config() -> ServerConfig {
    ServerConfig {
        engine: Engine::KeyDb,
        with_models: false,
        conn_read_timeout: Duration::from_millis(50),
        ..Default::default()
    }
}

fn start_shards(n: usize) -> Vec<DbServer> {
    (0..n).map(|_| DbServer::start(shard_config()).unwrap()).collect()
}

fn addrs(servers: &[DbServer]) -> Vec<SocketAddr> {
    servers.iter().map(|s| s.addr).collect()
}

fn replicated(addrs: &[SocketAddr], replicas: usize) -> ClusterClient {
    ClusterClient::connect_with(
        addrs,
        ClusterConfig { replicas, ..ClusterConfig::default() },
    )
    .unwrap()
}

/// Deterministic payload for (generation, rank) — byte-exact recovery
/// assertions compare against a reconstruction, not a stored copy.
fn payload(gen: u64, rank: usize) -> Tensor {
    let vals: Vec<f32> = (0..64).map(|i| (gen * 100_000 + rank as u64 * 1000 + i) as f32).collect();
    Tensor::from_f32(&[vals.len()], vals).unwrap()
}

fn write_generations(c: &mut ClusterClient, field: &str, gens: u64, ranks: usize) {
    for gen in 0..gens {
        for rank in 0..ranks {
            c.put_tensor(&tensor_key(field, rank, gen), &payload(gen, rank)).unwrap();
        }
    }
}

fn assert_generations_byte_exact(c: &mut ClusterClient, field: &str, gens: u64, ranks: usize) {
    for gen in 0..gens {
        for rank in 0..ranks {
            let key = tensor_key(field, rank, gen);
            let got = c.get_tensor(&key).unwrap_or_else(|e| panic!("lost {key}: {e}"));
            assert_eq!(got, payload(gen, rank), "payload for {key} not byte-exact");
        }
    }
}

// --- tentpole: kill any single shard, lose nothing ----------------------

#[test]
fn killing_any_single_shard_loses_no_replicated_data() {
    let gens = chaos_steps();
    let ranks = 4usize;
    for killed in 0..3usize {
        let mut servers = start_shards(3);
        let mut c = replicated(&addrs(&servers), 2);
        assert_eq!(c.replicas(), 2);
        write_generations(&mut c, "ck", gens, ranks);
        assert_eq!(c.failover_stats().read_failovers, 0, "healthy cluster needs no failover");

        let killed_addr = servers[killed].addr;
        servers[killed].simulate_crash();

        // Every pre-kill generation is still fully readable, byte-exact —
        // the surviving replica answers for the dead primary.
        assert_generations_byte_exact(&mut c, "ck", gens, ranks);
        let stats = c.failover_stats();
        assert!(
            stats.read_failovers > 0,
            "some keys' primary was shard {killed}; their reads must have failed over"
        );

        // Writes keep landing while the shard is down: degraded (one copy
        // instead of two) for keys that include the dead shard, reported
        // via shard_errors.  Spread extra keys so the key set provably
        // straddles the dead shard's replica pairs.
        for rank in 0..ranks {
            c.put_tensor(&tensor_key("ck", rank, gens), &payload(gens, rank)).unwrap();
        }
        for i in 0..12usize {
            c.put_tensor(&format!("ck-deg-{i}"), &payload(99, i)).unwrap();
        }
        assert!(c.failover_stats().degraded_ops > 0, "some post-kill writes ran degraded");
        assert!(
            c.shard_errors().iter().all(|e| e.shard == killed),
            "degraded reports name the dead shard: {:?}",
            c.shard_errors()
        );
        assert_generations_byte_exact(&mut c, "ck", gens + 1, ranks);

        // Restart the shard on its old address: after the breaker cooldown
        // the half-open probe reconnects and the ring is whole again (the
        // restarted store is empty, so reads still fail over for its keys).
        servers[killed] = DbServer::start(ServerConfig { addr: killed_addr, ..shard_config() })
            .unwrap_or_else(|e| panic!("rebind {killed_addr}: {e}"));
        std::thread::sleep(Duration::from_millis(300)); // > breaker_cooldown
        assert_generations_byte_exact(&mut c, "ck", gens + 1, ranks);
        assert!(
            c.failover_stats().shard_reconnects > 0,
            "half-open probe must reconnect the restarted shard"
        );

        for s in &mut servers {
            s.shutdown();
        }
    }
}

#[test]
fn unreplicated_cluster_degrades_cleanly_never_hangs() {
    let mut servers = start_shards(2);
    let mut c = replicated(&addrs(&servers), 1);
    let keys: Vec<String> = (0..32).map(|i| format!("uk{i}")).collect();
    for (i, k) in keys.iter().enumerate() {
        c.put_tensor(k, &payload(0, i)).unwrap();
    }
    servers[1].simulate_crash();

    let started = Instant::now();
    let (mut served, mut failed) = (0usize, 0usize);
    for (i, k) in keys.iter().enumerate() {
        match c.get_tensor(k) {
            Ok(t) => {
                assert_eq!(t, payload(0, i));
                served += 1;
            }
            Err(e) => {
                assert!(e.is_transient_io(), "dead-shard errors stay retryable: {e}");
                failed += 1;
            }
        }
    }
    // The key space straddles both shards, so both classes must occur:
    // clean service for survivors, clean transient errors for the dead one.
    assert!(served > 0 && failed > 0, "served={served} failed={failed}");
    // And "clean" includes bounded: refused connects + the open breaker
    // mean the whole sweep takes well under the 5 s I/O deadline once.
    assert!(
        started.elapsed() < Duration::from_secs(10),
        "degraded sweep must not hang: {:?}",
        started.elapsed()
    );

    // Aggregates return partial results with a per-shard error report.
    let info = c.info().unwrap();
    assert!(info.keys > 0);
    assert!(info.degraded_ops > 0, "aggregated info counts the degraded op");
    assert_eq!(c.shard_errors().len(), 1);
    assert_eq!(c.shard_errors()[0].shard, 1);
    let listed = c.list_keys("uk").unwrap();
    assert!(!listed.is_empty() && listed.len() < keys.len(), "partial key list");
    servers[0].shutdown();
}

#[test]
fn broadcast_ops_succeed_degraded_with_shard_error_report() {
    let mut servers = start_shards(3);
    let mut c = replicated(&addrs(&servers), 1);
    write_generations(&mut c, "bd", 2, 4);
    servers[2].simulate_crash();

    // set_retention / flush_all ride the same broadcast path put_model
    // uses: surviving shards apply it, the dead one is reported.
    c.set_retention(RetentionConfig::windowed(8, 0)).unwrap();
    assert_eq!(c.shard_errors().len(), 1, "one unreachable shard reported");
    assert_eq!(c.shard_errors()[0].shard, 2);
    assert!(c.shard_errors()[0].error.contains("shard") || !c.shard_errors()[0].error.is_empty());
    c.flush_all().unwrap();
    assert!(c.failover_stats().degraded_ops >= 2);
    // Flush reached the survivors.
    assert_eq!(c.info().unwrap().keys, 0);
    for s in &mut servers {
        s.shutdown();
    }
}

// --- tentpole: seeded probabilistic faults ------------------------------

#[test]
fn seeded_fault_plan_run_completes_byte_exact() {
    let gens = chaos_steps();
    let ranks = 4usize;
    let mut servers = start_shards(3);
    // Client-side fault plan: every shard connection misbehaves on a
    // schedule that is a pure function of SITU_CHAOS_SEED.  Intensity 2 ≈
    // a fault every ~50 byte-moving ops.
    let plan = Arc::new(FaultPlan::new(FaultConfig::with_intensity(chaos_seed(), 2.0)));
    let mut c = ClusterClient::connect_with(
        &addrs(&servers),
        ClusterConfig {
            replicas: 2,
            faults: Some(Arc::clone(&plan)),
            breaker_cooldown: Duration::from_millis(10),
            ..ClusterConfig::default()
        },
    )
    .unwrap();

    // Puts and gets are idempotent, so the transient-I/O retry class plus
    // replica failover must carry the run to completion whatever the plan
    // injects.
    let retry = RetryPolicy::backoff(Duration::from_millis(2), 60);
    for gen in 0..gens {
        for rank in 0..ranks {
            let key = tensor_key("sf", rank, gen);
            let (res, _) = retry
                .run_class(RetryClass::BusyOrTransientIo, || c.put_tensor(&key, &payload(gen, rank)));
            res.unwrap_or_else(|e| panic!("put {key} never landed: {e}"));
        }
    }
    for gen in 0..gens {
        for rank in 0..ranks {
            let key = tensor_key("sf", rank, gen);
            let (res, _) =
                retry.run_class(RetryClass::BusyOrTransientIo, || c.get_tensor(&key));
            let got = res.unwrap_or_else(|e| panic!("get {key} never answered: {e}"));
            assert_eq!(got, payload(gen, rank), "chaos run corrupted {key}");
        }
    }
    let counters = plan.counters();
    assert!(
        counters.delayed_ops + counters.severed_conns + counters.truncated_writes > 0,
        "the plan must actually have injected something: {counters:?}"
    );
    for s in &mut servers {
        s.shutdown();
    }
}

#[test]
fn kill_switch_mid_run_heals_after_revive() {
    let mut servers = start_shards(3);
    let plan = Arc::new(FaultPlan::new(FaultConfig::default()));
    let mut c = ClusterClient::connect_with(
        &addrs(&servers),
        ClusterConfig {
            replicas: 2,
            faults: Some(Arc::clone(&plan)),
            breaker_cooldown: Duration::from_millis(10),
            ..ClusterConfig::default()
        },
    )
    .unwrap();
    write_generations(&mut c, "kw", 3, 2);

    // kill(): every client connection fails at once — process death as the
    // sockets see it.  No data was lost server-side, so revive() + the
    // breaker's half-open probes restore full service.
    plan.kill();
    assert!(c.get_tensor(&tensor_key("kw", 0, 0)).is_err(), "killed plan fails transport");
    plan.revive();
    std::thread::sleep(Duration::from_millis(20));
    let retry = RetryPolicy::backoff(Duration::from_millis(2), 30);
    for gen in 0..3u64 {
        for rank in 0..2usize {
            let key = tensor_key("kw", rank, gen);
            let (res, _) = retry.run_class(RetryClass::BusyOrTransientIo, || c.get_tensor(&key));
            assert_eq!(res.unwrap(), payload(gen, rank));
        }
    }
    assert!(c.failover_stats().shard_reconnects > 0, "revive heals via reconnect");
    for s in &mut servers {
        s.shutdown();
    }
}

// --- tentpole: the trainer's gather path under shard loss ---------------

#[test]
fn gather_window_survives_shard_kill_byte_exact() {
    let gens = chaos_steps().max(4);
    let ranks = 4usize;
    let mut servers = start_shards(3);
    let mut c = replicated(&addrs(&servers), 2);
    write_generations(&mut c, "gw", gens, ranks);

    let latest = gens - 1;
    let window = gens.min(4);
    let mut dl = DataLoader::new(c, (0..ranks).collect(), "gw", 11);
    let before = dl.gather_window(latest, window).unwrap();

    // Kill a shard between two gathers: the second one runs its pipelined
    // reads through the failover rounds and must reproduce the first
    // gather exactly.
    servers[1].simulate_crash();
    let after = dl.gather_window(latest, window).unwrap();
    assert_eq!(before.len(), after.len());
    for (b, a) in before.iter().zip(&after) {
        assert_eq!(b, a, "window tensors diverged after shard kill");
    }
    // And against ground truth, not just self-consistency.
    let mut it = after.iter();
    for gen in (latest + 1 - window)..=latest {
        for rank in 0..ranks {
            assert_eq!(it.next().unwrap(), &payload(gen, rank));
        }
    }
    servers[0].shutdown();
    servers[2].shutdown();
}

// --- satellite: severed mid-split-frame ---------------------------------

#[test]
fn sever_mid_split_frame_leaves_store_clean_and_server_serving() {
    let server = DbServer::start(shard_config()).unwrap();

    // A torn put_tensor: the length prefix promises 256 bytes (the head a
    // begin_split_frame/end_split_frame pair would send), but the peer
    // dies after 12.  The connection thread must see EOF mid-frame and
    // exit without touching the store.
    {
        let mut s = TcpStream::connect(server.addr).unwrap();
        s.write_all(&256u32.to_le_bytes()).unwrap();
        s.write_all(&[0xAB; 12]).unwrap();
        s.flush().unwrap();
    } // dropped: RST/EOF mid-frame
    std::thread::sleep(Duration::from_millis(30));

    // Same tear, but the peer hangs instead of dying: the server's
    // conn-read timeout fires mid-frame and the thread exits cleanly.
    let hung = TcpStream::connect(server.addr).unwrap();
    (&hung).write_all(&256u32.to_le_bytes()).unwrap();
    (&hung).write_all(&[0xCD; 12]).unwrap();
    std::thread::sleep(Duration::from_millis(120)); // > conn_read_timeout (50 ms)

    // Store untouched, later connections fully served.
    let mut c = Client::connect(server.addr).unwrap();
    let info = c.info().unwrap();
    assert_eq!(info.keys, 0, "torn frames must not materialize keys");
    c.put_tensor("fine", &payload(1, 1)).unwrap();
    assert_eq!(c.get_tensor("fine").unwrap(), payload(1, 1));
    drop(hung);
}

// --- satellite: client I/O deadline -------------------------------------

#[test]
fn io_deadline_turns_a_hung_server_into_a_retryable_timeout() {
    // A listener that never accepts: the kernel completes the handshake
    // from the backlog, then nothing ever answers.
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let mut c = Client::connect_with(addr, Some(Duration::from_millis(150)), None).unwrap();

    let started = Instant::now();
    let err = c.info().expect_err("nothing ever replies");
    let elapsed = started.elapsed();
    assert!(err.is_transient_io(), "deadline expiry is retryable: {err}");
    assert!(elapsed >= Duration::from_millis(100), "deadline actually waited: {elapsed:?}");
    assert!(elapsed < Duration::from_secs(5), "deadline bounds the hang: {elapsed:?}");
    drop(listener);
}

// --- satellite: crash without the clean-shutdown spill barrier ----------

#[test]
fn simulate_crash_after_info_barrier_preserves_cold_tier() {
    let dir = std::env::temp_dir().join(format!("situ_chaos_spill_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let spill = SpillConfig {
        dir: dir.clone(),
        max_bytes: 0,
        segment_bytes: situ::db::spill::default_segment_bytes(),
    };
    let mut server = DbServer::start(ServerConfig {
        retention: RetentionConfig::windowed(1, 0),
        spill: Some(spill.clone()),
        ..shard_config()
    })
    .unwrap();

    let gens = 6u64;
    let mut c = Client::connect(server.addr).unwrap();
    for gen in 0..gens {
        c.put_tensor(&tensor_key("sp", 0, gen), &payload(gen, 0)).unwrap();
    }
    // `info` doubles as the durability barrier: it drains the spill queue,
    // so everything the window retired is on disk *before* the crash.
    let info = c.info().unwrap();
    assert!(info.spilled_keys >= gens - 1, "window-1 retirements spilled");
    server.simulate_crash(); // no clean-shutdown spill_sync

    // A replacement instance over the same directory replays the log:
    // every retired generation is still byte-exact via ColdGet.
    let server2 = DbServer::start(ServerConfig { spill: Some(spill), ..shard_config() }).unwrap();
    let mut c2 = Client::connect(server2.addr).unwrap();
    let cold = c2.cold_list("sp").unwrap();
    for gen in 0..gens - 1 {
        let key = tensor_key("sp", 0, gen);
        assert!(cold.contains(&key), "{key} missing from cold tier: {cold:?}");
        assert_eq!(c2.cold_get(&key).unwrap(), payload(gen, 0), "cold {key} not byte-exact");
    }
}

// --- tentpole: a real process kill --------------------------------------

/// `situ serve` child that is killed (never leaked) when the test ends.
struct ServeChild(std::process::Child);

impl Drop for ServeChild {
    fn drop(&mut self) {
        let _ = self.0.kill();
        let _ = self.0.wait();
    }
}

fn spawn_serve() -> (ServeChild, SocketAddr) {
    let mut child = std::process::Command::new(env!("CARGO_BIN_EXE_situ"))
        .args(["serve", "--port", "0", "--no-models"])
        .stdout(std::process::Stdio::piped())
        .stderr(std::process::Stdio::null())
        .spawn()
        .expect("spawn situ serve");
    // cmd_serve flushes the listening line exactly so pipes can parse it.
    let mut line = String::new();
    BufReader::new(child.stdout.as_mut().unwrap()).read_line(&mut line).unwrap();
    let addr = line
        .split("listening on ")
        .nth(1)
        .and_then(|rest| rest.split_whitespace().next())
        .unwrap_or_else(|| panic!("unparseable listening line: {line:?}"))
        .parse()
        .unwrap();
    (ServeChild(child), addr)
}

#[test]
fn real_process_kill_fails_over_with_zero_replicated_loss() {
    let (children, shard_addrs): (Vec<ServeChild>, Vec<SocketAddr>) =
        (0..3).map(|_| spawn_serve()).unzip();
    let mut children = children;
    let mut c = replicated(&shard_addrs, 2);
    let gens = chaos_steps().min(6);
    let ranks = 3usize;
    write_generations(&mut c, "pk", gens, ranks);

    // SIGKILL one shard process — the real thing, not a simulation.
    children[1].0.kill().unwrap();
    children[1].0.wait().unwrap();

    assert_generations_byte_exact(&mut c, "pk", gens, ranks);
    assert!(c.failover_stats().read_failovers > 0, "the dead process forced failovers");
    for rank in 0..ranks {
        c.put_tensor(&tensor_key("pk", rank, gens), &payload(gens, rank)).unwrap();
    }
    assert_generations_byte_exact(&mut c, "pk", gens + 1, ranks);
}

// --- tentpole: live reshard under load ----------------------------------

/// Converge a cluster that has never resharded onto a committed epoch
/// table spanning `shard_addrs` (a no-move reshard), so slot ownership is
/// enforced before the test starts moving data.  Returns the epoch.
fn install_initial_table(shard_addrs: &[SocketAddr], replicas: usize) -> u64 {
    let report = reshard(&ReshardConfig {
        addrs: shard_addrs.to_vec(),
        from_shards: 0,
        to_shards: 0,
        replicas,
        window: 0,
    })
    .unwrap();
    assert_eq!(report.moved_keys, 0, "a topology no-op moves no data");
    report.to_epoch
}

/// A cluster client that has fetched the installed slot table (production
/// clients refresh at startup; without it, routing starts from the static
/// even split over the whole address list).
fn cluster_with_table(shard_addrs: &[SocketAddr], replicas: usize) -> ClusterClient {
    let mut c = replicated(shard_addrs, replicas);
    c.refresh_slot_table().unwrap();
    c
}

/// Grow a loaded 3-shard cluster to 4 while a writer and a windowed
/// reader hammer it, then shrink back: zero governed generations lost,
/// no client ever surfaces an error, every shard converges on the
/// committed epoch, and stale clients are either bounced into a refetch
/// (full address list) or told to reconnect (short address list).
#[test]
fn live_reshard_3_to_4_under_load_loses_nothing() {
    let gens = chaos_steps().max(6);
    let ranks = 4usize;
    let mut servers = start_shards(4);
    let all = addrs(&servers);
    let first3 = all[..3].to_vec();

    // The cluster starts as 3 enforced shards; the 4th server is up but
    // owns no slots yet.
    assert_eq!(install_initial_table(&first3, 2), 1);
    let mut c = cluster_with_table(&all, 2);
    assert_eq!(c.epoch(), 1);
    write_generations(&mut c, "rs", gens, ranks);

    // Concurrent load across the cutover: a writer streaming fresh
    // generations and a reader gathering training windows.  Neither is
    // allowed to surface a single error or a non-exact byte.
    let stop = Arc::new(AtomicBool::new(false));
    let w_stop = Arc::clone(&stop);
    let w_addrs = all.clone();
    let writer = std::thread::spawn(move || {
        let mut wc = cluster_with_table(&w_addrs, 2);
        let mut done = 0u64;
        while !w_stop.load(Ordering::Relaxed) && done < 10_000 {
            for rank in 0..2usize {
                let key = tensor_key("live", rank, done);
                wc.put_tensor(&key, &payload(done, rank))
                    .unwrap_or_else(|e| panic!("write {key} errored mid-reshard: {e}"));
            }
            done += 1;
        }
        done
    });
    let r_stop = Arc::clone(&stop);
    let r_addrs = all.clone();
    let latest = gens - 1;
    let window = gens.min(4);
    let reader = std::thread::spawn(move || {
        let rc = cluster_with_table(&r_addrs, 2);
        let mut dl = DataLoader::new(rc, (0..4usize).collect(), "rs", 13);
        let mut sweeps = 0u64;
        loop {
            let got = dl
                .gather_window(latest, window)
                .unwrap_or_else(|e| panic!("gather errored mid-reshard: {e}"));
            let mut it = got.iter();
            for gen in (latest + 1 - window)..=latest {
                for rank in 0..4usize {
                    assert_eq!(
                        it.next().unwrap(),
                        &payload(gen, rank),
                        "gather diverged mid-reshard at gen {gen} rank {rank}"
                    );
                }
            }
            sweeps += 1;
            if r_stop.load(Ordering::Relaxed) {
                return sweeps;
            }
        }
    });

    // Grow 3 → 4, live.
    let report = reshard(&ReshardConfig {
        addrs: all.clone(),
        from_shards: 0,
        to_shards: 0,
        replicas: 2,
        window: 8,
    })
    .unwrap();
    assert_eq!(report.from_epoch, 1);
    assert_eq!(report.to_epoch, 3, "install + commit bump the epoch twice");
    assert!(report.moved_ranges >= 1 && report.moved_keys > 0, "a grow moves data: {report:?}");
    assert!(report.unreachable_shards.is_empty(), "every shard was up: {report:?}");

    stop.store(true, Ordering::Relaxed);
    let live_gens = writer.join().unwrap();
    let sweeps = reader.join().unwrap();
    assert!(sweeps > 0, "the reader must have gathered at least once");

    // Every shard converged on the committed epoch.
    for &a in &all {
        let (_, table) = Client::connect(a).unwrap().cluster_epoch().unwrap();
        assert_eq!(table.epoch, 3, "shard at {a} did not converge");
    }

    // Everything written before *and during* the reshard reads back
    // byte-exact through a fresh client on the new table.
    let mut after = cluster_with_table(&all, 2);
    assert_eq!(after.epoch(), 3);
    assert_generations_byte_exact(&mut after, "rs", gens, ranks);
    for gen in 0..live_gens {
        for rank in 0..2usize {
            let key = tensor_key("live", rank, gen);
            let got = after.get_tensor(&key).unwrap_or_else(|e| panic!("lost {key}: {e}"));
            assert_eq!(got, payload(gen, rank), "mid-reshard write {key} not byte-exact");
        }
    }
    // The new shard actually took ownership: some pre-reshard key now
    // routes to it and its streamed copy is served directly.
    let (mg, mr) = (0..gens)
        .flat_map(|g| (0..ranks).map(move |r| (g, r)))
        .find(|&(g, r)| after.slot_table().shard_for_key(&tensor_key("rs", r, g)) == 3)
        .expect("some pre-reshard key must now be owned by the new shard");
    assert_eq!(
        Client::connect(all[3]).unwrap().get_tensor(&tensor_key("rs", mr, mg)).unwrap(),
        payload(mg, mr),
        "the new owner serves its streamed copy"
    );

    // A client still holding only the original 3 addresses cannot adopt
    // the 4-shard table — it gets the designed reconnect error instead of
    // silently misrouting to a truncated ring.
    let mut short = replicated(&first3, 2);
    match short.refresh_slot_table() {
        Err(Error::Invalid(m)) => assert!(m.contains("full address list"), "{m}"),
        other => panic!("short-list client must be told to reconnect, got {other:?}"),
    }

    // A single-replica probe pinned to the stale 4-shard table: after the
    // shrink below, its only target for this key is the drained shard,
    // so the read *must* ride a `moved:` bounce into a refetch.
    let mut probe = cluster_with_table(&all, 1);
    assert_eq!(probe.epoch(), 3);
    let (pg, pr) = (0..gens)
        .flat_map(|g| (0..ranks).map(move |r| (g, r)))
        .find(|&(g, r)| probe.slot_table().shard_for_key(&tensor_key("rs", r, g)) == 3)
        .expect("some key is owned by shard 3 under the 4-shard table");
    let probe_key = tensor_key("rs", pr, pg);
    assert_eq!(probe.get_tensor(&probe_key).unwrap(), payload(pg, pr));
    assert_eq!(probe.epoch_refreshes(), 0, "fresh table, no bounce yet");

    // Shrink 4 → 3: the drained shard's slots stream back to survivors.
    let report = reshard(&ReshardConfig {
        addrs: all.clone(),
        from_shards: 0,
        to_shards: 3,
        replicas: 2,
        window: 0,
    })
    .unwrap();
    assert_eq!(report.from_epoch, 3);
    assert_eq!(report.to_epoch, 5);
    assert!(report.moved_keys > 0, "the drain moves the shard's data back: {report:?}");

    let got = probe.get_tensor(&probe_key).unwrap_or_else(|e| panic!("stale probe read: {e}"));
    assert_eq!(got, payload(pg, pr), "the bounced read still returns the exact data");
    assert!(probe.epoch_refreshes() > 0, "the drained shard's bounce forced a refetch");
    assert_eq!(probe.epoch(), 5);
    assert!(probe.slot_table().shard_for_key(&probe_key) < 3, "owner is a survivor now");

    // Post-shrink: all generations byte-exact, every shard (including the
    // drained one) converged on the committed epoch.
    let mut c3 = cluster_with_table(&all, 2);
    assert_eq!(c3.epoch(), 5);
    assert_generations_byte_exact(&mut c3, "rs", gens, ranks);
    for &a in &all {
        let (_, table) = Client::connect(a).unwrap().cluster_epoch().unwrap();
        assert_eq!(table.epoch, 5, "shard at {a} did not converge after the shrink");
    }
    for s in &mut servers {
        s.shutdown();
    }
}

// --- tentpole: shard killed mid-reshard, then backfilled ----------------

/// Kill a shard concurrently with a 3 → 4 reshard (`--replicas 2`): the
/// stream falls over to the surviving replica copies, the reshard
/// completes with zero replicated-data loss, the live shards converge on
/// the committed epoch — and the restarted shard is healed by the same
/// streaming path (`situ reshard --backfill`).
#[test]
fn shard_kill_mid_reshard_loses_no_replicated_data_and_backfill_heals() {
    let gens = chaos_steps().max(6);
    let ranks = 4usize;
    let mut servers = start_shards(4);
    let all = addrs(&servers);
    let first3 = all[..3].to_vec();
    assert_eq!(install_initial_table(&first3, 2), 1);
    let mut c = cluster_with_table(&all, 2);
    write_generations(&mut c, "mk", gens, ranks);

    // Kill shard 1 while the reshard runs.  Whatever the interleaving —
    // before the install, mid-stream, during cleanup — every one of its
    // keys has a live copy on its ring successor.
    let victim_addr = all[1];
    let victim = servers.remove(1);
    let killer = std::thread::spawn(move || {
        let mut v = victim;
        std::thread::sleep(Duration::from_millis(2));
        v.simulate_crash();
        v
    });
    let report = reshard(&ReshardConfig {
        addrs: all.clone(),
        from_shards: 0,
        to_shards: 0,
        replicas: 2,
        window: 4,
    })
    .unwrap_or_else(|e| panic!("reshard must survive a single shard kill: {e}"));
    let _victim = killer.join().unwrap();
    assert_eq!(report.to_epoch, 3);
    assert!(
        report.unreachable_shards.iter().all(|&s| s == 1),
        "only the killed shard may be missed: {report:?}"
    );

    // Zero replicated loss, shard still dead.  (A brand-new ClusterClient
    // cannot connect while a shard is down, so the pre-kill client
    // refreshes onto the committed table instead.)
    c.refresh_slot_table().unwrap();
    assert_eq!(c.epoch(), 3);
    assert_generations_byte_exact(&mut c, "mk", gens, ranks);
    for (i, &a) in all.iter().enumerate() {
        if i == 1 {
            continue;
        }
        let (_, table) = Client::connect(a).unwrap().cluster_epoch().unwrap();
        assert_eq!(table.epoch, 3, "live shard {i} did not converge");
    }

    // Restart the shard empty and stream it back to health — the same
    // windowed transfer path the reshard itself used.
    let mut restarted = DbServer::start(ServerConfig { addr: victim_addr, ..shard_config() })
        .unwrap_or_else(|e| panic!("rebind {victim_addr}: {e}"));
    let heal =
        backfill(&BackfillConfig { addrs: all.clone(), shard: 1, replicas: 2, window: 0 })
            .unwrap();
    assert_eq!(heal.epoch, 3, "backfill re-enrolls under the committed table");
    assert!(heal.ranges > 0 && heal.keys > 0, "shard 1 sits in replica rings: {heal:?}");
    let (_, table) = Client::connect(victim_addr).unwrap().cluster_epoch().unwrap();
    assert_eq!(table.epoch, 3, "the restarted shard holds the table again");

    // The restarted shard serves its own keys directly, byte-exact.
    let mut direct = Client::connect(victim_addr).unwrap();
    let mut served = 0usize;
    for gen in 0..gens {
        for rank in 0..ranks {
            let key = tensor_key("mk", rank, gen);
            if c.slot_table().shard_for_key(&key) == 1 {
                assert_eq!(
                    direct.get_tensor(&key).unwrap(),
                    payload(gen, rank),
                    "backfilled copy of {key} not byte-exact"
                );
                served += 1;
            }
        }
    }
    assert!(served > 0, "some key must be owned by the restarted shard");

    // And the cluster as a whole is whole again.
    std::thread::sleep(Duration::from_millis(300)); // > breaker cooldown
    assert_generations_byte_exact(&mut c, "mk", gens, ranks);
    restarted.shutdown();
    for s in &mut servers {
        s.shutdown();
    }
}
