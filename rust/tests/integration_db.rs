//! Integration tests: real TCP client ⇄ server round trips, both engines,
//! concurrency, failure injection, and the pipelined batch API — including
//! the round-trip accounting the redesign exists for (one request frame per
//! gather/wait) and deployment portability through `DataStore`.

use std::time::Duration;

use situ::client::{tensor_key, Client, ClusterClient, DataStore, Pipeline, PollConfig};
use situ::db::{DbServer, Engine, RetentionConfig, ServerConfig};
use situ::error::Error;
use situ::proto::{Request, Response};
use situ::tensor::{DType, Tensor};

fn start(engine: Engine) -> DbServer {
    // Short teardown knobs: this suite starts dozens of servers, and the
    // library defaults (1 s conn read timeout) would leave each one's
    // detached connection threads lingering for up to a second.
    DbServer::start(ServerConfig {
        engine,
        with_models: false,
        conn_read_timeout: Duration::from_millis(50),
        ..Default::default()
    })
    .unwrap()
}

fn t(v: Vec<f32>) -> Tensor {
    Tensor::from_f32(&[v.len()], v).unwrap()
}

fn frames(server: &DbServer) -> u64 {
    server.store().counters.frames.load(std::sync::atomic::Ordering::Relaxed)
}

fn quick_poll() -> PollConfig {
    PollConfig::new(Duration::from_millis(1), Duration::from_millis(20), Duration::from_secs(5))
}

#[test]
fn roundtrip_over_tcp_both_engines() {
    for engine in [Engine::Redis, Engine::KeyDb] {
        let server = start(engine);
        let mut c = Client::connect(server.addr).unwrap();
        let payload = t((0..1000).map(|i| i as f32).collect());
        c.put_tensor("k", &payload).unwrap();
        let back = c.get_tensor("k").unwrap();
        assert_eq!(back, payload);
        let info = c.info().unwrap();
        assert_eq!(info.keys, 1);
        assert_eq!(info.bytes, 4000);
        assert_eq!(info.models, 0);
        assert_eq!(info.engine, engine.name());
    }
}

#[test]
fn missing_key_and_delete_semantics() {
    let server = start(Engine::Redis);
    let mut c = Client::connect(server.addr).unwrap();
    assert!(matches!(c.get_tensor("nope"), Err(Error::KeyNotFound(_))));
    assert!(!c.del_tensor("nope").unwrap());
    c.put_tensor("x", &t(vec![1.0])).unwrap();
    assert!(c.del_tensor("x").unwrap());
    assert!(!c.exists("x").unwrap());
}

#[test]
fn metadata_and_list_keys() {
    let server = start(Engine::Redis);
    let mut c = Client::connect(server.addr).unwrap();
    assert_eq!(c.get_meta("latest_step").unwrap(), None);
    c.put_meta("latest_step", "17").unwrap();
    assert_eq!(c.get_meta("latest_step").unwrap(), Some("17".into()));
    for r in 0..3 {
        c.put_tensor(&tensor_key("field", r, 0), &t(vec![0.0])).unwrap();
    }
    let keys = c.list_keys("field_").unwrap();
    assert_eq!(keys.len(), 3);
    assert!(keys.windows(2).all(|w| w[0] < w[1]), "sorted");
}

#[test]
fn poll_key_waits_for_producer() {
    let server = start(Engine::Redis);
    let addr = server.addr;
    let producer = std::thread::spawn(move || {
        let mut c = Client::connect(addr).unwrap();
        std::thread::sleep(Duration::from_millis(120));
        c.put_tensor("late", &t(vec![5.0])).unwrap();
    });
    let mut c = Client::connect(server.addr).unwrap();
    c.poll_key("late", &quick_poll()).unwrap();
    assert!(c.exists("late").unwrap());
    producer.join().unwrap();
}

#[test]
fn poll_key_times_out() {
    let server = start(Engine::Redis);
    let mut c = Client::connect(server.addr).unwrap();
    let poll = PollConfig::new(
        Duration::from_millis(1),
        Duration::from_millis(10),
        Duration::from_millis(60),
    );
    let err = c.poll_key("never", &poll).unwrap_err();
    assert!(matches!(err, Error::Timeout(_)));
}

#[test]
fn poll_keys_is_one_round_trip_even_while_waiting() {
    // The server-side wait: the client sends one PollKeys frame and blocks;
    // the producer publishes on another connection; the waiting client's
    // frame count never grows.
    let server = start(Engine::KeyDb);
    let addr = server.addr;
    let mut c = Client::connect(server.addr).unwrap();
    // Snapshot before the producer exists so its 3 put frames are always
    // inside the measured window, however the threads interleave.
    let before = frames(&server);
    let producer = std::thread::spawn(move || {
        let mut c = Client::connect(addr).unwrap();
        std::thread::sleep(Duration::from_millis(100));
        for r in 0..3 {
            c.put_tensor(&tensor_key("w", r, 1), &t(vec![r as f32])).unwrap();
        }
    });
    let keys: Vec<String> = (0..3).map(|r| tensor_key("w", r, 1)).collect();
    c.poll_keys(&keys, &quick_poll()).unwrap();
    producer.join().unwrap();
    // The producer sent 3 frames; the poll itself was exactly 1.
    assert_eq!(frames(&server) - before, 3 + 1, "blocking wait costs one frame");
}

#[test]
fn many_concurrent_clients() {
    // One client per "rank", all hammering the same server (the paper's
    // one-SmartRedis-client-per-rank pattern).
    let server = start(Engine::KeyDb);
    let addr = server.addr;
    let mut handles = Vec::new();
    for rank in 0..12usize {
        handles.push(std::thread::spawn(move || {
            let mut c = Client::connect_retry(addr, 20, Duration::from_millis(10)).unwrap();
            for step in 0..20u64 {
                let key = tensor_key("f", rank, step);
                let payload = t(vec![rank as f32, step as f32]);
                c.put_tensor(&key, &payload).unwrap();
                assert_eq!(c.get_tensor(&key).unwrap(), payload);
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    let mut c = Client::connect(server.addr).unwrap();
    assert_eq!(c.info().unwrap().keys, 12 * 20);
}

#[test]
fn flush_all_clears() {
    let server = start(Engine::Redis);
    let mut c = Client::connect(server.addr).unwrap();
    c.put_tensor("a", &t(vec![1.0])).unwrap();
    c.flush_all().unwrap();
    let info = c.info().unwrap();
    assert_eq!((info.keys, info.bytes), (0, 0));
}

#[test]
fn cluster_client_shards_and_finds_keys() {
    let s1 = start(Engine::Redis);
    let s2 = start(Engine::Redis);
    let s3 = start(Engine::Redis);
    let mut cc = ClusterClient::connect(&[s1.addr, s2.addr, s3.addr]).unwrap();
    let n = 60;
    for i in 0..n {
        cc.put_tensor(&format!("key_{i}"), &t(vec![i as f32])).unwrap();
    }
    // Every key is retrievable through routing.
    for i in 0..n {
        assert_eq!(
            cc.get_tensor(&format!("key_{i}")).unwrap().to_f32().unwrap(),
            vec![i as f32]
        );
    }
    // Keys actually spread across shards.
    let per_shard: Vec<u64> = [&s1, &s2, &s3].iter().map(|s| s.store().n_keys()).collect();
    assert_eq!(per_shard.iter().sum::<u64>(), n as u64);
    assert!(per_shard.iter().all(|&k| k > 0), "all shards used: {per_shard:?}");
    // Merged listing sees everything.
    assert_eq!(cc.list_keys("key_").unwrap().len(), n);
}

#[test]
fn cluster_routing_every_key_on_exactly_one_shard() {
    let servers = [start(Engine::Redis), start(Engine::Redis), start(Engine::Redis)];
    let addrs: Vec<_> = servers.iter().map(|s| s.addr).collect();
    let mut cc = ClusterClient::connect(&addrs).unwrap();
    for i in 0..100 {
        let key = tensor_key("route", i % 7, i as u64);
        cc.put_tensor(&key, &t(vec![i as f32])).unwrap();
        let owners = servers.iter().filter(|s| s.store().exists(&key)).count();
        assert_eq!(owners, 1, "key '{key}' must land on exactly one shard");
    }
}

#[test]
fn cluster_full_parity_meta_poll_info() {
    // The ClusterClient side of the DataStore surface that used to be
    // Client-only: metadata, polling, info aggregation, batched gather.
    let servers = [start(Engine::KeyDb), start(Engine::KeyDb), start(Engine::KeyDb)];
    let addrs: Vec<_> = servers.iter().map(|s| s.addr).collect();
    let mut cc = ClusterClient::connect(&addrs).unwrap();

    cc.put_meta("latest_step", "3").unwrap();
    assert_eq!(cc.get_meta("latest_step").unwrap(), Some("3".into()));
    assert_eq!(cc.get_meta("absent").unwrap(), None);

    let keys: Vec<String> = (0..8).map(|r| tensor_key("p", r, 0)).collect();
    for (r, k) in keys.iter().enumerate() {
        cc.put_tensor(k, &t(vec![r as f32])).unwrap();
    }
    cc.poll_keys(&keys, &quick_poll()).unwrap();
    let got = cc.mget_tensors(&keys).unwrap();
    for (r, g) in got.iter().enumerate() {
        assert_eq!(g.to_f32().unwrap(), vec![r as f32]);
    }
    assert!(matches!(
        cc.poll_keys(&["p_rank99_step9".to_string()], &PollConfig::new(
            Duration::from_millis(1),
            Duration::from_millis(5),
            Duration::from_millis(50),
        )),
        Err(Error::Timeout(_))
    ));

    let info = cc.info().unwrap();
    assert_eq!(info.keys, 8 + 1, "aggregated key count spans shards");
    assert_eq!(info.engine, "keydb");

    cc.flush_all().unwrap();
    assert_eq!(cc.info().unwrap().keys, 0);
}

#[test]
fn batch_roundtrip_equals_sequential_calls() {
    // The same operation list run sequentially on one server and pipelined
    // on a fresh one must produce identical per-op results and store state.
    let a = t(vec![1.0, 2.0]);
    let b = t(vec![3.0]);

    let seq_server = start(Engine::Redis);
    let mut c = Client::connect(seq_server.addr).unwrap();
    c.put_tensor("a", &a).unwrap();
    c.put_tensor("b", &b).unwrap();
    let seq = vec![
        Response::Ok,
        Response::Ok,
        Response::Tensor(c.get_tensor("a").unwrap()),
        Response::Bool(c.exists("b").unwrap()),
        if c.del_tensor("b").unwrap() { Response::Ok } else { Response::NotFound },
        Response::Bool(c.exists("b").unwrap()),
        {
            c.put_meta("m", "v").unwrap();
            Response::Ok
        },
        Response::Meta(c.get_meta("m").unwrap().unwrap()),
        match c.get_meta("absent").unwrap() {
            Some(v) => Response::Meta(v),
            None => Response::NotFound,
        },
    ];
    let seq_keys = c.list_keys("").unwrap();

    let batch_server = start(Engine::Redis);
    let mut c = Client::connect(batch_server.addr).unwrap();
    let mut p = Pipeline::new();
    p.put_tensor("a", &a)
        .put_tensor("b", &b)
        .get_tensor("a")
        .exists("b")
        .del_tensor("b")
        .exists("b")
        .put_meta("m", "v")
        .get_meta("m")
        .get_meta("absent");
    let batched = c.execute(p).unwrap();
    assert_eq!(batched, seq, "batched results mirror sequential calls");
    assert_eq!(c.list_keys("").unwrap(), seq_keys, "store state matches");
}

#[test]
fn batch_is_one_frame_and_mget_tensors_share_one_allocation() {
    let server = start(Engine::Redis);
    let mut c = Client::connect(server.addr).unwrap();
    let keys: Vec<String> = (0..6).map(|r| tensor_key("g", r, 0)).collect();
    {
        let mut p = Pipeline::new();
        for (r, k) in keys.iter().enumerate() {
            p.put_tensor(k, &t(vec![r as f32; 64]));
        }
        let before = frames(&server);
        for r in c.execute(p).unwrap() {
            r.expect_ok().unwrap();
        }
        assert_eq!(frames(&server) - before, 1, "6 puts pipelined into one frame");
    }
    let before = frames(&server);
    let got = c.mget_tensors(&keys).unwrap();
    assert_eq!(frames(&server) - before, 1, "gather is one round trip");
    for (r, g) in got.iter().enumerate() {
        assert_eq!(g.to_f32().unwrap(), vec![r as f32; 64]);
    }
    // Zero-copy: every tensor in the batched reply aliases the single
    // response frame read off the socket.
    for w in got.windows(2) {
        assert!(
            w[0].data.shares_allocation(&w[1].data),
            "batch reply payloads must share the frame allocation"
        );
    }
    // And a missing key surfaces as KeyNotFound naming it.
    let mut bad = keys.clone();
    bad.push("g_rank99_step0".into());
    assert!(matches!(
        c.mget_tensors(&bad),
        Err(Error::KeyNotFound(k)) if k == "g_rank99_step0"
    ));
}

#[test]
fn error_mid_batch_reports_per_entry_results() {
    // The server runs without a model runtime, so RunModel fails at
    // *execution* time — the batch must report that failure in its slot and
    // keep executing the remaining entries.
    let server = start(Engine::Redis);
    let mut c = Client::connect(server.addr).unwrap();
    let reqs = vec![
        Request::PutTensor { key: "ok1".into(), tensor: t(vec![1.0]) },
        Request::GetTensor { key: "missing".into() },
        Request::RunModel {
            key: "ghost".into(),
            version: 0,
            in_keys: vec!["ok1".into()],
            out_keys: vec!["y".into()],
            device: situ::proto::Device::Cpu,
        },
        Request::PutTensor { key: "ok2".into(), tensor: t(vec![2.0]) },
    ];
    let results = c.exec_requests(&reqs).unwrap();
    assert_eq!(results[0], Response::Ok);
    assert_eq!(results[1], Response::NotFound);
    assert!(matches!(results[2], Response::Error(_)), "failed entry reports in place");
    assert_eq!(results[3], Response::Ok, "entries after a failure still run");
    assert!(c.exists("ok2").unwrap(), "batch was not aborted mid-way");
    // And the typed conversion layer surfaces the entry error as Remote.
    assert!(matches!(
        results[2].clone().expect_ok(),
        Err(Error::Remote(_))
    ));
}

#[test]
fn dataloader_single_round_trips_and_deployment_portability() {
    use situ::ml::DataLoader;

    // The acceptance property: gather and wait_for_step cost exactly one
    // request frame per call against a single database, and the identical
    // dataloader code runs against both deployments via DataStore.
    fn exercise<C: DataStore>(mut client: C, field: &str) -> Vec<Tensor> {
        for r in 0..4 {
            client.put_tensor(&tensor_key(field, r, 7), &t(vec![r as f32, 7.0])).unwrap();
        }
        let mut dl = DataLoader::new(client, vec![0, 1, 2, 3], field, 42);
        dl.wait_for_step(7, &quick_poll()).unwrap();
        dl.gather(7).unwrap()
    }

    // Co-located: count frames around the two per-epoch calls.
    let server = start(Engine::Redis);
    let mut client = Client::connect(server.addr).unwrap();
    for r in 0..4 {
        client.put_tensor(&tensor_key("solo", r, 7), &t(vec![r as f32, 7.0])).unwrap();
    }
    let mut dl = DataLoader::new(client, vec![0, 1, 2, 3], "solo", 42);
    let before = frames(&server);
    dl.wait_for_step(7, &quick_poll()).unwrap();
    assert_eq!(frames(&server) - before, 1, "wait_for_step is one request frame");
    let before = frames(&server);
    let got = dl.gather(7).unwrap();
    assert_eq!(frames(&server) - before, 1, "gather is one request frame");
    assert_eq!(got.len(), 4);

    // Same code against both deployments.
    let single = start(Engine::KeyDb);
    let got_single = exercise(Client::connect(single.addr).unwrap(), "port");
    let shards = [start(Engine::KeyDb), start(Engine::KeyDb)];
    let addrs: Vec<_> = shards.iter().map(|s| s.addr).collect();
    let got_cluster = exercise(ClusterClient::connect(&addrs).unwrap(), "port");
    assert_eq!(got_single, got_cluster, "identical data through either deployment");
}

#[test]
fn cluster_pipeline_partitions_and_reassembles_in_order() {
    let servers = [start(Engine::Redis), start(Engine::Redis), start(Engine::Redis)];
    let addrs: Vec<_> = servers.iter().map(|s| s.addr).collect();
    let mut cc = ClusterClient::connect(&addrs).unwrap();
    let n = 20usize;
    let mut p = Pipeline::new();
    for i in 0..n {
        p.put_tensor(&format!("pk_{i}"), &t(vec![i as f32]));
    }
    for r in cc.execute(p).unwrap() {
        r.expect_ok().unwrap();
    }
    let mut p = Pipeline::new();
    for i in 0..n {
        p.get_tensor(&format!("pk_{i}"));
    }
    let results = cc.execute(p).unwrap();
    assert_eq!(results.len(), n);
    for (i, r) in results.into_iter().enumerate() {
        // Order is submission order even though shards answered separately.
        let tensor = r.expect_tensor(&format!("pk_{i}")).unwrap();
        assert_eq!(tensor.to_f32().unwrap(), vec![i as f32]);
    }
    // Whole-database ops cannot be pipelined on a cluster.
    let mut p = Pipeline::new();
    p.push(Request::Info);
    assert!(matches!(cc.execute(p), Err(Error::Invalid(_))));
}

#[test]
fn connect_retry_does_not_sleep_after_final_attempt() {
    // Grab a port that nothing listens on.
    let dead = {
        let l = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        l.local_addr().unwrap()
    };
    let delay = Duration::from_millis(150);
    let t0 = std::time::Instant::now();
    let err = Client::connect_retry(dead, 3, delay);
    let elapsed = t0.elapsed();
    assert!(err.is_err());
    // 3 attempts → 2 inter-attempt sleeps (~300 ms); sleeping after the
    // final failure too would push past 3 delays.  Loopback
    // connection-refused is ~instant, so the bound is all sleep time.
    assert!(
        elapsed < delay * 3,
        "connect_retry slept after the last attempt: {elapsed:?}"
    );
}

#[test]
fn large_tensor_roundtrip() {
    let server = start(Engine::Redis);
    let mut c = Client::connect(server.addr).unwrap();
    let n = 4 << 20; // 16 MB payload
    let payload = Tensor {
        dtype: DType::F32,
        shape: vec![n],
        data: (0..4 * n).map(|i| (i % 251) as u8).collect::<Vec<u8>>().into(),
    };
    c.put_tensor("big", &payload).unwrap();
    assert_eq!(c.get_tensor("big").unwrap().data, payload.data);
}

#[test]
fn server_store_holds_client_payload_without_copy() {
    // The zero-copy ingress claim, observed through the co-located store
    // handle: after a TCP put, two in-process gets share one allocation.
    let server = start(Engine::Redis);
    let mut c = Client::connect(server.addr).unwrap();
    c.put_tensor("z", &t((0..4096).map(|i| i as f32).collect())).unwrap();
    let a = server.store().get_tensor("z").unwrap();
    let b = server.store().get_tensor("z").unwrap();
    assert!(a.data.shares_allocation(&b.data), "store hands out views, not copies");
    assert_eq!(a.data.as_ptr(), b.data.as_ptr());
    assert_eq!(a.to_f32().unwrap()[4095], 4095.0);
}

#[test]
fn batched_put_stores_payload_without_copy() {
    // The pipelined ingress path preserves zero-copy: a tensor sent inside
    // a Batch frame is stored as a view into that frame.
    let server = start(Engine::KeyDb);
    let mut c = Client::connect(server.addr).unwrap();
    let mut p = Pipeline::new();
    p.put_tensor("bz", &t((0..2048).map(|i| i as f32).collect()));
    p.put_meta("step", "0");
    for r in c.execute(p).unwrap() {
        r.expect_ok().unwrap();
    }
    let a = server.store().get_tensor("bz").unwrap();
    let b = server.store().get_tensor("bz").unwrap();
    assert!(a.data.shares_allocation(&b.data));
    assert_eq!(a.to_f32().unwrap()[2047], 2047.0);
}

#[test]
fn reader_keeps_old_payload_across_overwrite_over_tcp() {
    let server = start(Engine::KeyDb);
    let mut writer = Client::connect(server.addr).unwrap();
    writer.put_tensor("k", &t(vec![1.0; 512])).unwrap();
    // A reader fetches, then the key is overwritten and deleted; the
    // fetched tensor must stay byte-valid (it owns a refcount on the old
    // buffer).
    let mut reader = Client::connect(server.addr).unwrap();
    let old = reader.get_tensor("k").unwrap();
    writer.put_tensor("k", &t(vec![2.0; 512])).unwrap();
    writer.del_tensor("k").unwrap();
    assert_eq!(old.to_f32().unwrap(), vec![1.0; 512]);
}

#[test]
fn server_survives_malformed_frames() {
    use std::io::Write;
    let server = start(Engine::Redis);
    // Write garbage on a raw socket; the server answers with an error or
    // drops that connection but keeps serving others.
    {
        let mut raw = std::net::TcpStream::connect(server.addr).unwrap();
        raw.write_all(&[9, 0, 0, 0, 0xee, 1, 2, 3, 4, 5, 6, 7, 8]).unwrap();
    }
    std::thread::sleep(Duration::from_millis(50));
    let mut c = Client::connect(server.addr).unwrap();
    c.put_tensor("ok", &t(vec![1.0])).unwrap();
    assert!(c.exists("ok").unwrap());
}

#[test]
fn reconnect_after_drop() {
    let server = start(Engine::Redis);
    let mut c1 = Client::connect(server.addr).unwrap();
    c1.put_tensor("persist", &t(vec![2.0])).unwrap();
    drop(c1);
    let mut c2 = Client::connect(server.addr).unwrap();
    assert_eq!(c2.get_tensor("persist").unwrap().to_f32().unwrap(), vec![2.0]);
}

#[test]
fn overwrite_is_last_writer_wins() {
    let server = start(Engine::KeyDb);
    let mut c = Client::connect(server.addr).unwrap();
    c.put_tensor("k", &t(vec![1.0, 2.0])).unwrap();
    c.put_tensor("k", &t(vec![9.0])).unwrap();
    assert_eq!(c.get_tensor("k").unwrap().to_f32().unwrap(), vec![9.0]);
    assert_eq!(c.info().unwrap().bytes, 4);
}

#[test]
fn retention_over_tcp_window_and_counters() {
    let server = start(Engine::Redis);
    let mut c = Client::connect(server.addr).unwrap();
    c.set_retention(RetentionConfig::windowed(2, 0)).unwrap();
    for step in 0..5u64 {
        for r in 0..3 {
            c.put_tensor(&tensor_key("w", r, step), &t(vec![step as f32; 16])).unwrap();
        }
    }
    let keys = c.list_keys("w_").unwrap();
    assert_eq!(keys.len(), 2 * 3, "two retained generations: {keys:?}");
    assert!(keys.iter().all(|k| k.ends_with("step3") || k.ends_with("step4")));
    // Evicted keys answer with a clean NotFound, and a short poll for them
    // times out instead of wedging.
    assert!(matches!(
        c.get_tensor(&tensor_key("w", 0, 0)),
        Err(Error::KeyNotFound(_))
    ));
    assert!(matches!(
        c.poll_keys(
            &[tensor_key("w", 0, 0)],
            &PollConfig::new(
                Duration::from_millis(1),
                Duration::from_millis(5),
                Duration::from_millis(40),
            )
        ),
        Err(Error::Timeout(_))
    ));
    let info = c.info().unwrap();
    assert_eq!(info.evicted_keys, 3 * 3);
    assert_eq!(info.evicted_bytes, 9 * 64);
    assert_eq!(info.bytes, 6 * 64);
    assert!(info.high_water_bytes >= info.bytes);
    assert_eq!(info.busy_rejections, 0);
    // The INFO reply carries the active policy and per-field pressure.
    assert_eq!((info.retention_window, info.retention_max_bytes), (2, 0));
    assert_eq!(info.fields.len(), 1, "{:?}", info.fields);
    let fp = &info.fields[0];
    assert_eq!(fp.field, "w");
    assert_eq!(fp.generations, 2);
    assert_eq!(fp.resident_bytes, 6 * 64);
    assert_eq!(fp.evicted_keys, 9);
    assert_eq!(fp.evicted_bytes, 9 * 64);
}

#[test]
fn put_backpressure_surfaces_as_busy() {
    let server = start(Engine::Redis);
    let mut c = Client::connect(server.addr).unwrap();
    // Cap fits one field's two-generation window exactly (2 × 64 B).
    c.set_retention(RetentionConfig::windowed(2, 128)).unwrap();
    c.put_tensor(&tensor_key("f", 0, 0), &t(vec![0.0; 16])).unwrap();
    c.put_tensor(&tensor_key("f", 0, 1), &t(vec![1.0; 16])).unwrap();
    // A different field cannot fit: explicit backpressure, window intact.
    let err = c.put_tensor(&tensor_key("g", 0, 0), &t(vec![2.0; 16])).unwrap_err();
    assert!(matches!(err, Error::Busy(_)), "{err}");
    assert!(c.exists(&tensor_key("f", 0, 0)).unwrap());
    assert!(c.exists(&tensor_key("f", 0, 1)).unwrap());
    // Appending within the same field retires its own oldest generation.
    c.put_tensor(&tensor_key("f", 0, 2), &t(vec![3.0; 16])).unwrap();
    assert!(!c.exists(&tensor_key("f", 0, 0)).unwrap());
    let info = c.info().unwrap();
    assert_eq!(info.busy_rejections, 1);
    assert!(info.bytes <= 128);
}

#[test]
fn del_keys_is_one_frame_with_per_key_results() {
    let server = start(Engine::KeyDb);
    let mut c = Client::connect(server.addr).unwrap();
    let keys: Vec<String> = (0..5).map(|r| tensor_key("d", r, 0)).collect();
    for k in &keys[..3] {
        c.put_tensor(k, &t(vec![1.0])).unwrap();
    }
    let before = frames(&server);
    let deleted = c.del_keys(&keys).unwrap();
    assert_eq!(frames(&server) - before, 1, "multi-delete is one round trip");
    assert_eq!(deleted, 3, "only resident keys count");
    assert_eq!(c.list_keys("d_").unwrap().len(), 0);
    assert_eq!(c.del_keys(&[]).unwrap(), 0, "empty delete is a no-op");
}

#[test]
fn cluster_parity_del_keys_retention_and_windowed_gather() {
    use situ::ml::DataLoader;

    let servers = [start(Engine::KeyDb), start(Engine::KeyDb)];
    let addrs: Vec<_> = servers.iter().map(|s| s.addr).collect();
    let mut cc = ClusterClient::connect(&addrs).unwrap();

    // set_retention broadcasts to every shard instance.
    cc.set_retention(RetentionConfig::windowed(3, 0)).unwrap();
    for s in &servers {
        assert_eq!(s.store().retention(), RetentionConfig::windowed(3, 0));
    }

    // Publish 8 generations of 4 ranks; each shard windows the generations
    // it holds, so cluster-wide the newest 3 are always fully retained.
    let ranks = 4usize;
    for step in 0..8u64 {
        for r in 0..ranks {
            cc.put_tensor(&tensor_key("cf", r, step), &t(vec![step as f32, r as f32]))
                .unwrap();
        }
    }
    // Every key of the newest 3 global generations survives (at most 2
    // global generations are newer than step 5, so step-5..7 keys are
    // always inside their shard's local window)...
    let survivors = cc.list_keys("cf_").unwrap();
    for step in 5..8u64 {
        for r in 0..ranks {
            assert!(survivors.contains(&tensor_key("cf", r, step)), "step {step} evicted");
        }
    }
    // ...and no shard retains more than `window` generations of the field
    // (a shard that happens to miss the newest generations may keep
    // correspondingly older ones — the window is per instance).
    for s in &servers {
        let mut local_steps: Vec<u64> = s
            .store()
            .list_keys("cf_")
            .iter()
            .map(|k| situ::db::parse_step_key(k).unwrap().1)
            .collect();
        local_steps.sort_unstable();
        local_steps.dedup();
        assert!(local_steps.len() <= 3, "shard over its window: {local_steps:?}");
    }
    // info aggregates eviction counters across instances.
    let info = cc.info().unwrap();
    let per_store: u64 = servers
        .iter()
        .map(|s| s.store().counters.evicted_keys.load(std::sync::atomic::Ordering::Relaxed))
        .sum();
    assert!(per_store > 0, "eviction must have run");
    assert_eq!(info.evicted_keys, per_store);

    // The windowed dataloader runs unchanged on the clustered deployment
    // (its pipelined gets route per shard) and matches a co-located run.
    let mut dl = DataLoader::new(cc, (0..ranks).collect(), "cf", 9);
    dl.wait_for_step(7, &quick_poll()).unwrap();
    let clustered = dl.gather_window(7, 2).unwrap();
    assert_eq!(clustered.len(), 2 * ranks, "two complete generations");

    let solo = start(Engine::KeyDb);
    let mut sc = Client::connect(solo.addr).unwrap();
    for step in 6..8u64 {
        for r in 0..ranks {
            sc.put_tensor(&tensor_key("cf", r, step), &t(vec![step as f32, r as f32]))
                .unwrap();
        }
    }
    let mut dl2 = DataLoader::new(sc, (0..ranks).collect(), "cf", 9);
    let colocated = dl2.gather_window(7, 2).unwrap();
    assert_eq!(clustered, colocated, "identical window through either deployment");

    // del_keys partitions across shards and sums the results.
    let victims: Vec<String> = (0..ranks).map(|r| tensor_key("cf", r, 7)).collect();
    assert_eq!(dl.client.del_keys(&victims).unwrap(), ranks as u64);
    for k in &victims {
        assert!(!dl.client.exists(k).unwrap());
    }
}

#[test]
fn cluster_info_merges_spill_counters_and_routes_cold_reads() {
    // Two shards, each with its own spill directory: a field's generations
    // scatter across shards, each shard windows (and spills) what it holds
    // locally, and the aggregated `info` must merge the per-field spill
    // counters by field name — the same merge path as FieldPressure — while
    // cold reads route to the shard that evicted the key.
    let base = std::env::temp_dir()
        .join(format!("situ_cluster_spill_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&base);
    let mk = |i: usize| {
        DbServer::start(ServerConfig {
            engine: Engine::KeyDb,
            with_models: false,
            retention: RetentionConfig::windowed(1, 0),
            spill: Some(situ::db::SpillConfig::new(base.join(format!("shard{i}")))),
            conn_read_timeout: Duration::from_millis(50),
            ..Default::default()
        })
        .unwrap()
    };
    let servers = [mk(0), mk(1)];
    let addrs: Vec<_> = servers.iter().map(|s| s.addr).collect();
    let mut cc = ClusterClient::connect(&addrs).unwrap();

    let ranks = 4usize;
    let steps = 5u64;
    for step in 0..steps {
        for r in 0..ranks {
            let val = (step * 10 + r as u64) as f32;
            cc.put_tensor(&tensor_key("sp", r, step), &t(vec![val; 8])).unwrap();
        }
    }

    // Aggregated spill counters equal the per-shard sums (the INFO round
    // trip itself syncs each shard's spill writer first).
    let info = cc.info().unwrap();
    let per_shard_spilled: u64 = servers.iter().map(|s| s.store().spill_counters().0).sum();
    assert!(per_shard_spilled > 0, "eviction must have spilled somewhere");
    assert_eq!(info.spilled_keys, per_shard_spilled, "global counters sum across shards");
    assert_eq!(info.spilled_keys, info.evicted_keys, "every eviction spilled");
    let fp = info.fields.iter().find(|f| f.field == "sp").expect("merged field entry");
    assert_eq!(
        fp.spilled_keys, info.spilled_keys,
        "per-field spill counters merged by name across shards"
    );
    assert_eq!(fp.spilled_bytes, info.spilled_bytes);

    // ColdList merges across shards; every evicted key is in exactly one
    // shard's cold tier and reads back byte-exact through routing.
    let cold = cc.cold_list("sp_").unwrap();
    assert_eq!(cold.len() as u64, info.spilled_keys);
    assert!(cold.windows(2).all(|w| w[0] < w[1]), "merged + sorted");
    let hot = cc.list_keys("sp_").unwrap();
    for key in &cold {
        assert!(!hot.contains(key), "cold and hot tiers are disjoint here");
        let (_, step) = situ::db::parse_step_key(key).unwrap();
        let rank: u64 = key
            .split("_rank")
            .nth(1)
            .and_then(|s| s.split("_step").next())
            .and_then(|s| s.parse().ok())
            .unwrap();
        let back = cc.cold_get(key).unwrap();
        assert_eq!(
            back.to_f32().unwrap(),
            vec![(step * 10 + rank) as f32; 8],
            "cold read through cluster routing is byte-exact: {key}"
        );
    }
    // A never-spilled key misses cleanly through the cluster too.
    assert!(matches!(
        cc.cold_get("sp_rank0_step99"),
        Err(Error::KeyNotFound(_))
    ));
    drop(cc);
    drop(servers);
    let _ = std::fs::remove_dir_all(&base);
}

#[test]
fn windowed_gather_skips_retired_generations() {
    let server = start(Engine::Redis);
    let mut c = Client::connect(server.addr).unwrap();
    c.set_retention(RetentionConfig::windowed(2, 0)).unwrap();
    for step in 0..6u64 {
        for r in 0..2 {
            c.put_tensor(&tensor_key("sk", r, step), &t(vec![step as f32])).unwrap();
        }
    }
    // Ask for a window of 4 ending at the latest step: generations 2 and 3
    // are already retired, so only the retained 4 and 5 come back.
    let mut dl = situ::ml::DataLoader::new(c, vec![0, 1], "sk", 3);
    let got = dl.gather_window(5, 4).unwrap();
    assert_eq!(got.len(), 2 * 2);
    for tensor in &got {
        let v = tensor.to_f32().unwrap()[0];
        assert!(v == 4.0 || v == 5.0, "retired generation leaked: {v}");
    }
    // A missing *latest* generation is an error, not a silent skip.
    assert!(matches!(
        dl.gather_window(9, 2),
        Err(Error::KeyNotFound(_))
    ));
}

#[test]
fn configured_timeouts_speed_up_teardown() {
    // The knobs exist so tests stop paying up to 1 s of shutdown latency
    // per connection: with a 25 ms read timeout the connection thread
    // notices the stop flag and closes the socket almost immediately.
    let mut server = DbServer::start(ServerConfig {
        engine: Engine::Redis,
        with_models: false,
        conn_read_timeout: Duration::from_millis(25),
        ..Default::default()
    })
    .unwrap();
    let mut c = Client::connect(server.addr).unwrap();
    c.put_tensor("x", &t(vec![1.0])).unwrap();

    let t0 = std::time::Instant::now();
    server.shutdown();
    // Joining the accept thread costs at most one backoff ceiling.
    assert!(t0.elapsed() < Duration::from_millis(500), "accept join: {:?}", t0.elapsed());

    // The connection thread notices the stop flag within ~one read timeout
    // and closes its socket; under the old fixed 1 s timeout full teardown
    // took up to a second per connection.  Wait out a few timeouts so the
    // thread has certainly exited, then the dead socket must fail fast.
    std::thread::sleep(Duration::from_millis(150));
    let err = c.get_tensor("x");
    assert!(err.is_err(), "server is down");
    assert!(
        t0.elapsed() < Duration::from_millis(900),
        "teardown latency: {:?}",
        t0.elapsed()
    );
}

#[test]
fn ttl_retention_over_tcp_reclaims_stalled_producer() {
    // A producer publishes two generations, then stalls.  With a TTL
    // policy, an `info` round trip (which sweeps expired data server-side)
    // reclaims them; counters attribute the eviction to the TTL tier.
    let server = start(Engine::KeyDb);
    let mut c = Client::connect(server.addr).unwrap();
    c.set_retention(RetentionConfig { window: 4, max_bytes: 0, ttl_ms: 250 }).unwrap();
    for step in 0..2u64 {
        for r in 0..2 {
            c.put_tensor(&tensor_key("stall", r, step), &t(vec![step as f32; 8])).unwrap();
        }
    }
    let info = c.info().unwrap();
    assert_eq!(info.ttl_expired_keys, 0, "fresh data survives the sweep");
    assert_eq!(info.retention_ttl_ms, 250);
    assert_eq!(info.keys, 4);
    std::thread::sleep(Duration::from_millis(500));
    let info = c.info().unwrap();
    assert_eq!(info.ttl_expired_keys, 4, "stalled generations reclaimed");
    assert_eq!(info.keys, 0);
    assert_eq!(info.bytes, 0);
    assert_eq!(info.evicted_keys, 4, "TTL expiry counts as eviction");
    assert!(c.list_keys("stall_").unwrap().is_empty());
}

#[test]
fn put_tensor_retry_rides_out_transient_pressure() {
    use situ::client::RetryPolicy;

    // Cap fits exactly one 64-byte untracked key.  While "hog" is resident
    // a put of equal size under another key gets Busy; a concurrent delete
    // of the hog lets the retrying put land.
    let server = start(Engine::KeyDb);
    let addr = server.addr;
    let mut c = Client::connect(addr).unwrap();
    c.set_retention(RetentionConfig { window: 2, max_bytes: 64, ttl_ms: 0 }).unwrap();
    // A protected step-key window occupies the whole cap: nothing evictable.
    c.put_tensor(&tensor_key("f", 0, 0), &t(vec![0.0; 8])).unwrap();
    c.put_tensor(&tensor_key("f", 0, 1), &t(vec![1.0; 8])).unwrap();

    // Immediate-fail policy surfaces Busy as before.
    let err = c
        .put_tensor_retry(&tensor_key("g", 0, 0), &t(vec![2.0; 8]), &RetryPolicy::Fail)
        .unwrap_err();
    assert!(matches!(err, Error::Busy(_)), "{err}");

    // A consumer frees the window from another connection while the
    // producer retries under a deadline policy.
    let freer = std::thread::spawn(move || {
        let mut c2 = Client::connect(addr).unwrap();
        std::thread::sleep(Duration::from_millis(80));
        c2.del_keys(&[tensor_key("f", 0, 0), tensor_key("f", 0, 1)]).unwrap();
    });
    let policy = RetryPolicy::deadline(Duration::from_millis(10), Duration::from_secs(10));
    let retries = c
        .put_tensor_retry(&tensor_key("g", 0, 0), &t(vec![2.0; 8]), &policy)
        .unwrap();
    assert!(retries > 0, "the put must have waited out the pressure");
    freer.join().unwrap();
    assert!(c.exists(&tensor_key("g", 0, 0)).unwrap());
    let info = c.info().unwrap();
    assert!(info.busy_rejections >= 2, "each rejected attempt is counted");
}
