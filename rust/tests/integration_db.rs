//! Integration tests: real TCP client ⇄ server round trips, both engines,
//! concurrency, failure injection.

use std::time::Duration;

use situ::client::{tensor_key, Client, ClusterClient};
use situ::db::{DbServer, Engine, ServerConfig};
use situ::error::Error;
use situ::tensor::{DType, Tensor};

fn start(engine: Engine) -> DbServer {
    DbServer::start(ServerConfig { engine, with_models: false, ..Default::default() }).unwrap()
}

fn t(v: Vec<f32>) -> Tensor {
    Tensor::from_f32(&[v.len()], v).unwrap()
}

#[test]
fn roundtrip_over_tcp_both_engines() {
    for engine in [Engine::Redis, Engine::KeyDb] {
        let server = start(engine);
        let mut c = Client::connect(server.addr).unwrap();
        let payload = t((0..1000).map(|i| i as f32).collect());
        c.put_tensor("k", &payload).unwrap();
        let back = c.get_tensor("k").unwrap();
        assert_eq!(back, payload);
        let (keys, bytes, _ops, models, name) = c.info().unwrap();
        assert_eq!(keys, 1);
        assert_eq!(bytes, 4000);
        assert_eq!(models, 0);
        assert_eq!(name, engine.name());
    }
}

#[test]
fn missing_key_and_delete_semantics() {
    let server = start(Engine::Redis);
    let mut c = Client::connect(server.addr).unwrap();
    assert!(matches!(c.get_tensor("nope"), Err(Error::KeyNotFound(_))));
    assert!(!c.del_tensor("nope").unwrap());
    c.put_tensor("x", &t(vec![1.0])).unwrap();
    assert!(c.del_tensor("x").unwrap());
    assert!(!c.exists("x").unwrap());
}

#[test]
fn metadata_and_list_keys() {
    let server = start(Engine::Redis);
    let mut c = Client::connect(server.addr).unwrap();
    assert_eq!(c.get_meta("latest_step").unwrap(), None);
    c.put_meta("latest_step", "17").unwrap();
    assert_eq!(c.get_meta("latest_step").unwrap(), Some("17".into()));
    for r in 0..3 {
        c.put_tensor(&tensor_key("field", r, 0), &t(vec![0.0])).unwrap();
    }
    let keys = c.list_keys("field_").unwrap();
    assert_eq!(keys.len(), 3);
    assert!(keys.windows(2).all(|w| w[0] < w[1]), "sorted");
}

#[test]
fn poll_key_waits_for_producer() {
    let server = start(Engine::Redis);
    let addr = server.addr;
    let producer = std::thread::spawn(move || {
        let mut c = Client::connect(addr).unwrap();
        std::thread::sleep(Duration::from_millis(120));
        c.put_tensor("late", &t(vec![5.0])).unwrap();
    });
    let mut c = Client::connect(server.addr).unwrap();
    c.poll_key("late", Duration::from_millis(10), Duration::from_secs(5)).unwrap();
    assert!(c.exists("late").unwrap());
    producer.join().unwrap();
}

#[test]
fn poll_key_times_out() {
    let server = start(Engine::Redis);
    let mut c = Client::connect(server.addr).unwrap();
    let err = c
        .poll_key("never", Duration::from_millis(5), Duration::from_millis(60))
        .unwrap_err();
    assert!(matches!(err, Error::Timeout(_)));
}

#[test]
fn many_concurrent_clients() {
    // One client per "rank", all hammering the same server (the paper's
    // one-SmartRedis-client-per-rank pattern).
    let server = start(Engine::KeyDb);
    let addr = server.addr;
    let mut handles = Vec::new();
    for rank in 0..12usize {
        handles.push(std::thread::spawn(move || {
            let mut c = Client::connect_retry(addr, 20, Duration::from_millis(10)).unwrap();
            for step in 0..20u64 {
                let key = tensor_key("f", rank, step);
                let payload = t(vec![rank as f32, step as f32]);
                c.put_tensor(&key, &payload).unwrap();
                assert_eq!(c.get_tensor(&key).unwrap(), payload);
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    let mut c = Client::connect(server.addr).unwrap();
    let (keys, ..) = c.info().unwrap();
    assert_eq!(keys, 12 * 20);
}

#[test]
fn flush_all_clears() {
    let server = start(Engine::Redis);
    let mut c = Client::connect(server.addr).unwrap();
    c.put_tensor("a", &t(vec![1.0])).unwrap();
    c.flush_all().unwrap();
    let (keys, bytes, ..) = c.info().unwrap();
    assert_eq!((keys, bytes), (0, 0));
}

#[test]
fn cluster_client_shards_and_finds_keys() {
    let s1 = start(Engine::Redis);
    let s2 = start(Engine::Redis);
    let s3 = start(Engine::Redis);
    let mut cc = ClusterClient::connect(&[s1.addr, s2.addr, s3.addr]).unwrap();
    let n = 60;
    for i in 0..n {
        cc.put_tensor(&format!("key_{i}"), &t(vec![i as f32])).unwrap();
    }
    // Every key is retrievable through routing.
    for i in 0..n {
        assert_eq!(
            cc.get_tensor(&format!("key_{i}")).unwrap().to_f32().unwrap(),
            vec![i as f32]
        );
    }
    // Keys actually spread across shards.
    let per_shard: Vec<u64> = [&s1, &s2, &s3].iter().map(|s| s.store().n_keys()).collect();
    assert_eq!(per_shard.iter().sum::<u64>(), n as u64);
    assert!(per_shard.iter().all(|&k| k > 0), "all shards used: {per_shard:?}");
    // Merged listing sees everything.
    assert_eq!(cc.list_keys("key_").unwrap().len(), n);
}

#[test]
fn large_tensor_roundtrip() {
    let server = start(Engine::Redis);
    let mut c = Client::connect(server.addr).unwrap();
    let n = 4 << 20; // 16 MB payload
    let payload = Tensor {
        dtype: DType::F32,
        shape: vec![n],
        data: (0..4 * n).map(|i| (i % 251) as u8).collect::<Vec<u8>>().into(),
    };
    c.put_tensor("big", &payload).unwrap();
    assert_eq!(c.get_tensor("big").unwrap().data, payload.data);
}

#[test]
fn server_store_holds_client_payload_without_copy() {
    // The zero-copy ingress claim, observed through the co-located store
    // handle: after a TCP put, two in-process gets share one allocation.
    let server = start(Engine::Redis);
    let mut c = Client::connect(server.addr).unwrap();
    c.put_tensor("z", &t((0..4096).map(|i| i as f32).collect())).unwrap();
    let a = server.store().get_tensor("z").unwrap();
    let b = server.store().get_tensor("z").unwrap();
    assert!(a.data.shares_allocation(&b.data), "store hands out views, not copies");
    assert_eq!(a.data.as_ptr(), b.data.as_ptr());
    assert_eq!(a.to_f32().unwrap()[4095], 4095.0);
}

#[test]
fn reader_keeps_old_payload_across_overwrite_over_tcp() {
    let server = start(Engine::KeyDb);
    let mut writer = Client::connect(server.addr).unwrap();
    writer.put_tensor("k", &t(vec![1.0; 512])).unwrap();
    // A reader fetches, then the key is overwritten and deleted; the
    // fetched tensor must stay byte-valid (it owns a refcount on the old
    // buffer).
    let mut reader = Client::connect(server.addr).unwrap();
    let old = reader.get_tensor("k").unwrap();
    writer.put_tensor("k", &t(vec![2.0; 512])).unwrap();
    writer.del_tensor("k").unwrap();
    assert_eq!(old.to_f32().unwrap(), vec![1.0; 512]);
}

#[test]
fn server_survives_malformed_frames() {
    use std::io::Write;
    let server = start(Engine::Redis);
    // Write garbage on a raw socket; the server answers with an error or
    // drops that connection but keeps serving others.
    {
        let mut raw = std::net::TcpStream::connect(server.addr).unwrap();
        raw.write_all(&[9, 0, 0, 0, 0xee, 1, 2, 3, 4, 5, 6, 7, 8]).unwrap();
    }
    std::thread::sleep(Duration::from_millis(50));
    let mut c = Client::connect(server.addr).unwrap();
    c.put_tensor("ok", &t(vec![1.0])).unwrap();
    assert!(c.exists("ok").unwrap());
}

#[test]
fn reconnect_after_drop() {
    let server = start(Engine::Redis);
    let mut c1 = Client::connect(server.addr).unwrap();
    c1.put_tensor("persist", &t(vec![2.0])).unwrap();
    drop(c1);
    let mut c2 = Client::connect(server.addr).unwrap();
    assert_eq!(c2.get_tensor("persist").unwrap().to_f32().unwrap(), vec![2.0]);
}

#[test]
fn overwrite_is_last_writer_wins() {
    let server = start(Engine::KeyDb);
    let mut c = Client::connect(server.addr).unwrap();
    c.put_tensor("k", &t(vec![1.0, 2.0])).unwrap();
    c.put_tensor("k", &t(vec![9.0])).unwrap();
    assert_eq!(c.get_tensor("k").unwrap().to_f32().unwrap(), vec![9.0]);
    let (_, bytes, ..) = c.info().unwrap();
    assert_eq!(bytes, 4);
}
