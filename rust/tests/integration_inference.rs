//! Integration: the full RedisAI-analogue inference path over TCP —
//! put_model → put_tensor → run_model → get_tensor (paper Fig 1b), plus
//! failure injection on the model path.

use situ::client::{Client, DataStore};
use situ::db::{DbServer, Engine, ServerConfig};
use situ::proto::Device;
use situ::tensor::Tensor;
use situ::util::rng::Rng;

fn artifacts() -> Option<std::path::PathBuf> {
    let dir = situ::db::server::artifacts_dir();
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("skipping: artifacts not built");
        None
    }
}

#[test]
fn three_step_inference_over_tcp() {
    let Some(dir) = artifacts() else { return };
    let server = DbServer::start(ServerConfig::default()).unwrap();
    let mut c = Client::connect(server.addr).unwrap();

    // Model upload from the client side (the paper allows driver- or
    // simulation-side upload; we exercise the client path).
    c.put_model_from_file("resnet", &dir.join("resnet_lite_b1.hlo.txt")).unwrap();

    let mut rng = Rng::new(5);
    let x = Tensor::from_f32(&[1, 3, 64, 64], rng.normal_vec_f32(3 * 64 * 64)).unwrap();
    // Step 1: send inference data.
    c.put_tensor("in_0", &x).unwrap();
    // Step 2: evaluate on a GPU slot.
    c.run_model("resnet", &["in_0".into()], &["out_0".into()], Device::Gpu(1)).unwrap();
    // Step 3: retrieve predictions.
    let pred = c.get_tensor("out_0").unwrap();
    assert_eq!(pred.shape, vec![1, 1000]);
    let (_, mn, mx) = pred.f32_stats().unwrap();
    assert!(mn.is_finite() && mx.is_finite() && mx > mn);

    assert_eq!(c.info().unwrap().models, 1);
}

#[test]
fn encoder_inference_compresses_snapshot() {
    // The paper's target use case: encode a flow snapshot in the DB, store
    // only the latent (1700x-style compression).
    let Some(dir) = artifacts() else { return };
    let m = situ::runtime::Manifest::load_dir(&dir).unwrap();
    let server = DbServer::start(ServerConfig::default()).unwrap();
    let mut c = Client::connect(server.addr).unwrap();
    c.put_model_from_file("encoder", &dir.join(&m.artifact("encoder").unwrap().file)).unwrap();

    // Inputs: encoder params (from params_init.bin) then the snapshot.
    let state = situ::ml::ParamState::load_init(&m, &dir).unwrap();
    let mut in_keys = Vec::new();
    for name in &m.enc_param_order {
        let i = m.param_order.iter().position(|p| p == name).unwrap();
        let key = format!("param_{name}");
        c.put_tensor(&key, &state.params[i]).unwrap();
        in_keys.push(key);
    }
    let mut rng = Rng::new(11);
    let snap = Tensor::from_f32(
        &[m.model.channels, m.model.n_points],
        rng.normal_vec_f32(m.model.channels * m.model.n_points),
    )
    .unwrap();
    c.put_tensor("snap_0", &snap).unwrap();
    in_keys.push("snap_0".into());

    c.run_model("encoder", &in_keys, &["latent_0".into()], Device::Gpu(0)).unwrap();
    let z = c.get_tensor("latent_0").unwrap();
    assert_eq!(z.shape, vec![m.model.latent]);
    let factor = snap.nbytes() as f64 / z.nbytes() as f64;
    assert!(
        (factor - m.model.compression_factor).abs() < 1.0,
        "compression factor {factor} vs manifest {}",
        m.model.compression_factor
    );
}

#[test]
fn run_model_without_model_errors() {
    let server = DbServer::start(ServerConfig::default()).unwrap();
    let mut c = Client::connect(server.addr).unwrap();
    c.put_tensor("x", &Tensor::from_f32(&[1], vec![0.0]).unwrap()).unwrap();
    let err = c
        .run_model("ghost", &["x".into()], &["y".into()], Device::Cpu)
        .unwrap_err();
    assert!(err.to_string().contains("model not found"), "{err}");
}

#[test]
fn run_model_with_missing_input_errors() {
    let Some(dir) = artifacts() else { return };
    let server = DbServer::start(ServerConfig::default()).unwrap();
    let mut c = Client::connect(server.addr).unwrap();
    c.put_model_from_file("resnet", &dir.join("resnet_lite_b1.hlo.txt")).unwrap();
    let err = c
        .run_model("resnet", &["absent".into()], &["y".into()], Device::Cpu)
        .unwrap_err();
    assert!(err.to_string().contains("not found"), "{err}");
}

#[test]
fn run_model_wrong_output_arity_errors() {
    let Some(dir) = artifacts() else { return };
    let server = DbServer::start(ServerConfig::default()).unwrap();
    let mut c = Client::connect(server.addr).unwrap();
    c.put_model_from_file("resnet", &dir.join("resnet_lite_b1.hlo.txt")).unwrap();
    let mut rng = Rng::new(5);
    let x = Tensor::from_f32(&[1, 3, 64, 64], rng.normal_vec_f32(3 * 64 * 64)).unwrap();
    c.put_tensor("x", &x).unwrap();
    let err = c
        .run_model("resnet", &["x".into()], &["a".into(), "b".into()], Device::Cpu)
        .unwrap_err();
    assert!(err.to_string().contains("outputs"), "{err}");
}

#[test]
fn model_runtime_disabled_reports_cleanly() {
    let server =
        DbServer::start(ServerConfig { with_models: false, ..Default::default() }).unwrap();
    let mut c = Client::connect(server.addr).unwrap();
    let err = c.put_model("m", "HloModule m").unwrap_err();
    assert!(err.to_string().contains("disabled"), "{err}");
}

#[test]
fn concurrent_inference_across_gpu_slots() {
    let Some(dir) = artifacts() else { return };
    let server = DbServer::start(ServerConfig::default()).unwrap();
    let addr = server.addr;
    {
        let mut c = Client::connect(addr).unwrap();
        c.put_model_from_file("resnet", &dir.join("resnet_lite_b1.hlo.txt")).unwrap();
    }
    let mut handles = Vec::new();
    for rank in 0..4usize {
        handles.push(std::thread::spawn(move || {
            let mut c = Client::connect(addr).unwrap();
            let device = situ::ai::ModelRuntime::device_for_rank(rank);
            let mut rng = Rng::new(rank as u64);
            let x = Tensor::from_f32(&[1, 3, 64, 64], rng.normal_vec_f32(3 * 64 * 64)).unwrap();
            for it in 0..3 {
                let ik = format!("in_{rank}_{it}");
                let ok = format!("out_{rank}_{it}");
                c.put_tensor(&ik, &x).unwrap();
                c.run_model("resnet", &[ik], &[ok.clone()], device).unwrap();
                let out = c.get_tensor(&ok).unwrap();
                assert_eq!(out.shape, vec![1, 1000]);
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
}
