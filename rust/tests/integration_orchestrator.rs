//! Integration: orchestrator end-to-end — drivers, deployments, a miniature
//! in-situ training run (the paper §4 workflow at test scale), and the
//! reproducer loops.

use situ::config::{Deployment, RunConfig};
use situ::orchestrator::driver::{run_insitu_training, Driver, InSituTrainingConfig};
use situ::sim::reproducer::{run_data_loop, ReproducerConfig};

fn artifacts() -> Option<std::path::PathBuf> {
    let dir = situ::db::server::artifacts_dir();
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("skipping: artifacts not built");
        None
    }
}

#[test]
fn driver_launches_colocated_plan() {
    let mut cfg = RunConfig::default();
    cfg.nodes = 2;
    let mut driver = Driver::launch(&cfg, false).unwrap();
    assert_eq!(driver.addrs().len(), 2, "one DB per node");
    // Both instances reachable.
    for addr in driver.addrs() {
        use situ::client::DataStore;
        let mut c = situ::client::Client::connect(addr).unwrap();
        assert_eq!(c.info().unwrap().keys, 0);
    }
    driver.shutdown();
}

#[test]
fn driver_launches_clustered_plan() {
    let mut cfg = RunConfig::default();
    cfg.deployment = Deployment::Clustered { db_nodes: 3 };
    let mut driver = Driver::launch(&cfg, false).unwrap();
    assert_eq!(driver.addrs().len(), 3, "dedicated DB shards");
    assert_eq!(driver.plan.total_nodes(), cfg.nodes + 3);
    driver.shutdown();
}

#[test]
fn reproducer_measures_all_components() {
    let mut cfg = RunConfig::default();
    cfg.nodes = 1;
    let mut driver = Driver::launch(&cfg, false).unwrap();
    let times = run_data_loop(&ReproducerConfig {
        addr: driver.primary_addr(),
        ranks: 4,
        bytes_per_rank: 64 * 1024,
        iterations: 5,
        warmup: 1,
        compute_secs: 0.0,
        retry: situ::client::RetryPolicy::Fail,
    })
    .unwrap();
    let snap = times.snapshot();
    assert_eq!(snap["client_init"].count(), 4, "one init per rank");
    assert_eq!(snap["send"].count(), 4 * 5, "warmup discarded");
    assert_eq!(snap["retrieve"].count(), 4 * 5);
    assert!(snap["send"].mean() > 0.0);
    driver.shutdown();
}

#[test]
fn insitu_training_end_to_end_miniature() {
    // The §4 workflow at test scale: CFD producer + co-located DB + trainer.
    // The full-scale run lives in examples/insitu_training.rs.
    let Some(dir) = artifacts() else { return };
    let cfg = InSituTrainingConfig {
        artifacts_dir: dir,
        grid: (12, 10, 8),
        nu: 2e-3,
        sim_ranks: 2,
        ml_ranks: 1,
        epochs: 6,
        snapshot_every: 2,
        solver_steps: 16,
        seed: 3,
        ..Default::default()
    };
    let report = run_insitu_training(&cfg).unwrap();
    assert_eq!(report.history.len(), 6);
    // Losses finite and the optimizer actually stepped.
    for log in &report.history {
        assert!(log.train_loss.is_finite());
        assert!(log.val_loss.is_finite());
        assert!(log.val_rel_err > 0.0);
    }
    assert!(report.history.last().unwrap().step >= 6);
    // Overhead accounting present: solver table includes the paper's rows.
    let md = report.solver_table.render_markdown();
    for row in ["equation_formation", "equation_solution", "client_init", "send", "metadata"] {
        assert!(md.contains(row), "missing solver component {row}:\n{md}");
    }
    let md2 = report.trainer_table.render_markdown();
    for row in ["client_init", "metadata", "retrieve", "train", "total_training"] {
        assert!(md2.contains(row), "missing trainer component {row}:\n{md2}");
    }
    // The paper's headline: framework overhead is a small fraction of the
    // PDE integration cost.  At test scale the solver is tiny, so only
    // sanity-bound it.
    assert!(report.solver_overhead_frac < 5.0, "overhead {:.3}", report.solver_overhead_frac);
}

#[test]
fn insitu_training_windowed_bounded_memory() {
    // The bounded-memory §4 workflow: a retention window on the store and
    // a moving training window on the consumer.  16 solver steps at
    // snapshot_every=2 publish 8 generations; retention keeps 4, so
    // eviction demonstrably ran while training still converged on the
    // retained window.  (retention_window comfortably exceeds the trainer
    // window: the producer would have to advance 3 generations inside the
    // trainer's microsecond meta-read→gather gap to race it, and each
    // generation costs two real solver steps.)
    let Some(dir) = artifacts() else { return };
    let cfg = InSituTrainingConfig {
        artifacts_dir: dir,
        grid: (12, 10, 8),
        nu: 2e-3,
        sim_ranks: 2,
        ml_ranks: 1,
        epochs: 5,
        snapshot_every: 2,
        solver_steps: 16,
        seed: 3,
        window: 2,
        retention_window: 4,
        ..Default::default()
    };
    let report = run_insitu_training(&cfg).unwrap();
    assert_eq!(report.history.len(), 5);
    for log in &report.history {
        assert!(log.train_loss.is_finite());
        assert!(log.val_loss.is_finite());
    }
    assert!(report.db.evicted_keys > 0, "retention retired old generations");
    assert!(
        report.db.high_water_bytes >= report.db.bytes,
        "high-water tracks peak residency"
    );
    assert_eq!(report.db.busy_rejections, 0, "no backpressure without a byte cap");
    // Governor accounting: every snapshot published, none skipped/dropped
    // (no pressure), and the per-field pressure reached INFO.
    assert!(report.snapshots_published > 0);
    assert_eq!(report.governor.published, report.snapshots_published);
    assert_eq!(report.governor.skipped + report.governor.dropped, 0);
    assert_eq!(report.db.retention_window, 4);
    assert_eq!(report.db.fields.len(), 1, "{:?}", report.db.fields);
    assert_eq!(report.db.fields[0].field, "field");
    assert!(report.db.fields[0].evicted_keys > 0);
}

#[test]
fn insitu_training_overwrite_mode_holds_one_generation() {
    // The paper's overwrite publishing mode: stable keys keep exactly one
    // generation per field resident, no retention policy required.
    let Some(dir) = artifacts() else { return };
    let cfg = InSituTrainingConfig {
        artifacts_dir: dir,
        grid: (12, 10, 8),
        nu: 2e-3,
        sim_ranks: 2,
        ml_ranks: 1,
        epochs: 4,
        snapshot_every: 2,
        solver_steps: 12,
        seed: 3,
        overwrite: true,
        ..Default::default()
    };
    let report = run_insitu_training(&cfg).unwrap();
    assert_eq!(report.history.len(), 4);
    for log in &report.history {
        assert!(log.train_loss.is_finite());
    }
    // One stable tensor key per sim rank plus the latest_step metadata.
    assert_eq!(report.db.keys, cfg.sim_ranks as u64 + 1, "flat by construction");
    assert_eq!(report.db.evicted_keys, 0, "overwrite needs no eviction");
}

#[test]
fn trainer_times_out_without_producer() {
    let Some(dir) = artifacts() else { return };
    let mut cfg = RunConfig::default();
    cfg.nodes = 1;
    let mut driver = Driver::launch(&cfg, false).unwrap();
    let t_cfg = situ::ml::TrainerConfig {
        db_addr: driver.primary_addr(),
        ml_ranks: 1,
        sim_ranks: 1,
        epochs: 1,
        field: "field".into(),
        poll: situ::client::PollConfig::with_max_wait(std::time::Duration::from_millis(100)),
        ..Default::default()
    };
    let exec = situ::runtime::Executor::new().unwrap();
    let mut trainer = situ::ml::Trainer::new(t_cfg, &dir, exec).unwrap();
    let err = trainer.run().unwrap_err();
    assert!(matches!(err, situ::error::Error::Timeout(_)), "{err}");
    driver.shutdown();
}
