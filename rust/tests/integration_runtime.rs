//! Integration: PJRT runtime executing the real AOT artifacts.
//!
//! Requires `make artifacts` (skipped otherwise).  These tests are the
//! rust-side half of the L1/L2 correctness story: the python suite proves
//! kernel == oracle; here we prove the lowered HLO text loads, compiles and
//! produces sane numbers through the `xla` crate.

use std::path::PathBuf;

use situ::ml::{stack_batch, ParamState};
use situ::runtime::{Executor, Manifest};
use situ::tensor::Tensor;
use situ::util::rng::Rng;

fn artifacts() -> Option<PathBuf> {
    let dir = situ::db::server::artifacts_dir();
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("skipping: artifacts not built");
        None
    }
}

fn synth_batch(manifest: &Manifest, b: usize, seed: u64) -> Tensor {
    // Smooth-ish field + noise, like the python test fixture.
    let c = manifest.model.channels;
    let n = manifest.model.n_points;
    let mut rng = Rng::new(seed);
    let mut data = Vec::with_capacity(b * c * n);
    for _ in 0..b {
        for ch in 0..c {
            for i in 0..n {
                let x = i as f32 / n as f32;
                data.push(
                    (2.0 * std::f32::consts::PI * x + ch as f32).sin()
                        + 0.1 * rng.normal() as f32,
                );
            }
        }
    }
    Tensor::from_f32(&[b, c, n], data).unwrap()
}

#[test]
fn manifest_parses_and_validates() {
    let Some(dir) = artifacts() else { return };
    let m = Manifest::load_dir(&dir).unwrap();
    assert_eq!(m.model.channels, 4);
    assert_eq!(m.model.latent, 100);
    assert_eq!(m.param_order.len(), m.model.n_param_tensors);
    // train_step signature: 3P+2 in, 3P+2 out.
    let ts = m.artifact("train_step").unwrap();
    assert_eq!(ts.inputs.len(), 3 * m.model.n_param_tensors + 2);
    assert_eq!(ts.outputs.len(), 3 * m.model.n_param_tensors + 2);
}

#[test]
fn encoder_runs_and_is_deterministic() {
    let Some(dir) = artifacts() else { return };
    let m = Manifest::load_dir(&dir).unwrap();
    let exec = Executor::new().unwrap();
    exec.load_artifact("encoder", &dir.join(&m.artifact("encoder").unwrap().file)).unwrap();
    let state = ParamState::load_init(&m, &dir).unwrap();
    // Encoder takes enc params (in enc_param_order) + f.
    let enc_idx: Vec<usize> = m
        .enc_param_order
        .iter()
        .map(|k| m.param_order.iter().position(|p| p == k).unwrap())
        .collect();
    let mut inputs: Vec<Tensor> = enc_idx.iter().map(|&i| state.params[i].clone()).collect();
    let f = synth_batch(&m, 1, 3);
    let sample = Tensor::from_f32(
        &[m.model.channels, m.model.n_points],
        f.to_f32().unwrap()[..m.model.channels * m.model.n_points].to_vec(),
    )
    .unwrap();
    inputs.push(sample);
    let out1 = exec.execute("encoder", inputs.clone()).unwrap();
    let out2 = exec.execute("encoder", inputs).unwrap();
    assert_eq!(out1.len(), 1);
    assert_eq!(out1[0].shape, vec![m.model.latent]);
    assert_eq!(out1[0].data, out2[0].data, "deterministic");
    let (mean, mn, mx) = out1[0].f32_stats().unwrap();
    assert!(mean.is_finite() && mn.is_finite() && mx.is_finite());
    assert!(mx > mn, "latent is not constant");
}

#[test]
fn autoencoder_roundtrip_reconstructs_scale() {
    let Some(dir) = artifacts() else { return };
    let m = Manifest::load_dir(&dir).unwrap();
    let exec = Executor::new().unwrap();
    exec.load_artifact("autoencoder", &dir.join(&m.artifact("autoencoder").unwrap().file))
        .unwrap();
    let state = ParamState::load_init(&m, &dir).unwrap();
    let mut inputs = state.params.clone();
    let f = synth_batch(&m, 1, 5);
    let sample = Tensor::from_f32(
        &[m.model.channels, m.model.n_points],
        f.to_f32().unwrap()[..m.model.channels * m.model.n_points].to_vec(),
    )
    .unwrap();
    inputs.push(sample.clone());
    let out = exec.execute("autoencoder", inputs).unwrap();
    assert_eq!(out[0].shape, sample.shape);
    // Untrained: reconstruction won't match, but must be finite and bounded.
    let (_, mn, mx) = out[0].f32_stats().unwrap();
    assert!(mn.is_finite() && mx.is_finite() && mx.abs() < 1e4);
}

#[test]
fn train_step_decreases_loss_through_pjrt() {
    // The core L2-through-L3 signal: repeated fused train_step executions
    // from rust reduce the MSE on a fixed batch.
    let Some(dir) = artifacts() else { return };
    let m = Manifest::load_dir(&dir).unwrap();
    let exec = Executor::new().unwrap();
    exec.load_artifact("train_step", &dir.join(&m.artifact("train_step").unwrap().file))
        .unwrap();
    let mut state = ParamState::load_init(&m, &dir).unwrap();
    let batch = synth_batch(&m, m.model.batch, 7);
    let mut losses = Vec::new();
    for _ in 0..12 {
        let out = exec.execute("train_step", state.train_step_inputs(batch.clone())).unwrap();
        losses.push(state.absorb_train_step(out).unwrap());
    }
    assert_eq!(state.step, 12);
    assert!(losses.iter().all(|l| l.is_finite()));
    assert!(
        losses.last().unwrap() < losses.first().unwrap(),
        "loss must decrease: {losses:?}"
    );
}

#[test]
fn grad_step_plus_apply_adam_matches_fused() {
    let Some(dir) = artifacts() else { return };
    let m = Manifest::load_dir(&dir).unwrap();
    let exec = Executor::new().unwrap();
    for name in ["train_step", "grad_step", "apply_adam"] {
        exec.load_artifact(name, &dir.join(&m.artifact(name).unwrap().file)).unwrap();
    }
    let batch = synth_batch(&m, m.model.batch, 11);

    let mut fused = ParamState::load_init(&m, &dir).unwrap();
    let out = exec.execute("train_step", fused.train_step_inputs(batch.clone())).unwrap();
    let loss_fused = fused.absorb_train_step(out).unwrap();

    let mut ddp = ParamState::load_init(&m, &dir).unwrap();
    let mut out = exec.execute("grad_step", ddp.grad_step_inputs(batch)).unwrap();
    let grads = out.split_off(1);
    let loss_ddp = out.pop().unwrap().first_f32().unwrap();
    let out = exec.execute("apply_adam", ddp.apply_adam_inputs(grads)).unwrap();
    ddp.absorb_apply_adam(out).unwrap();

    assert!((loss_fused - loss_ddp).abs() < 1e-5, "{loss_fused} vs {loss_ddp}");
    for (a, b) in fused.params.iter().zip(&ddp.params) {
        let va = a.to_f32().unwrap();
        let vb = b.to_f32().unwrap();
        for (x, y) in va.iter().zip(&vb) {
            assert!((x - y).abs() < 1e-5, "params diverge: {x} vs {y}");
        }
    }
}

#[test]
fn eval_step_reports_loss_and_relative_error() {
    let Some(dir) = artifacts() else { return };
    let m = Manifest::load_dir(&dir).unwrap();
    let exec = Executor::new().unwrap();
    exec.load_artifact("eval_step", &dir.join(&m.artifact("eval_step").unwrap().file)).unwrap();
    let state = ParamState::load_init(&m, &dir).unwrap();
    let mut inputs = state.params.clone();
    inputs.push(synth_batch(&m, m.model.batch, 13));
    let out = exec.execute("eval_step", inputs).unwrap();
    let loss = out[0].first_f32().unwrap();
    let rel = out[1].first_f32().unwrap();
    assert!(loss > 0.0 && loss.is_finite());
    assert!(rel > 0.0 && rel < 100.0, "relative error sane: {rel}");
}

#[test]
fn resnet_lite_batches_agree() {
    let Some(dir) = artifacts() else { return };
    let exec = Executor::new().unwrap();
    for b in [1usize, 4] {
        let name = format!("resnet_lite_b{b}");
        exec.load_artifact(&name, &dir.join(format!("{name}.hlo.txt"))).unwrap();
    }
    let mut rng = Rng::new(3);
    let x1: Vec<f32> = rng.normal_vec_f32(3 * 64 * 64);
    // batch-4 input = the same sample repeated.
    let mut x4 = Vec::with_capacity(4 * x1.len());
    for _ in 0..4 {
        x4.extend_from_slice(&x1);
    }
    let o1 = exec
        .execute("resnet_lite_b1", vec![Tensor::from_f32(&[1, 3, 64, 64], x1).unwrap()])
        .unwrap();
    let o4 = exec
        .execute("resnet_lite_b4", vec![Tensor::from_f32(&[4, 3, 64, 64], x4).unwrap()])
        .unwrap();
    assert_eq!(o1[0].shape, vec![1, 1000]);
    assert_eq!(o4[0].shape, vec![4, 1000]);
    let v1 = o1[0].to_f32().unwrap();
    let v4 = o4[0].to_f32().unwrap();
    for i in 0..1000 {
        assert!((v1[i] - v4[i]).abs() < 2e-4, "row 0 mismatch at {i}");
        assert!((v1[i] - v4[3000 + i]).abs() < 2e-4, "row 3 mismatch at {i}");
    }
}

#[test]
fn missing_artifact_is_model_not_found() {
    let exec = Executor::new().unwrap();
    let err = exec.execute("never_loaded", vec![]).unwrap_err();
    assert!(matches!(err, situ::error::Error::ModelNotFound(_)));
}

#[test]
fn truncated_artifact_fails_to_compile() {
    let Some(dir) = artifacts() else { return };
    let text = std::fs::read_to_string(dir.join("encoder.hlo.txt")).unwrap();
    let exec = Executor::new().unwrap();
    let half = &text[..text.len() / 2];
    assert!(exec.load_hlo_text("broken", half).is_err());
}

#[test]
fn dataloader_stack_matches_trainstep_batch_shape() {
    let Some(dir) = artifacts() else { return };
    let m = Manifest::load_dir(&dir).unwrap();
    let sample = Tensor::from_f32(
        &[m.model.channels, m.model.n_points],
        vec![0.5; m.model.channels * m.model.n_points],
    )
    .unwrap();
    let batch = stack_batch(&[&sample], m.model.batch).unwrap();
    let want = &m.artifact("train_step").unwrap().inputs.last().unwrap().shape;
    assert_eq!(&batch.shape, want);
}
