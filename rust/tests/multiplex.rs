//! Connection-multiplexing tests: many tagged requests in flight on ONE
//! socket, with replies paired by tag rather than arrival order — plus the
//! latency bugs the async core fixed (batch polls summing timeouts, idle
//! connections pinning threads, slow shutdown) pinned as regressions.
//!
//! The battery runs under both the single-reactor seed topology and a
//! 4-reactor shard (see [`reactor_counts`]): tag pairing, ordering, and
//! fault semantics must be indistinguishable across reactor counts.

use std::net::TcpStream;
use std::sync::Arc;
use std::time::{Duration, Instant};

use situ::client::{Client, DataStore, PollConfig};
use situ::db::{DbServer, Engine, ServerConfig};
use situ::proto::{read_frame, write_frame, Request, Response};
use situ::tensor::Tensor;
use situ::util::fault::{FaultConfig, FaultPlan};

/// Reactor counts the battery sweeps.  `SITU_REACTORS=N` pins the whole
/// battery to one count (the CI matrix uses this to re-run the suite
/// against a 4-way shard); unset, each parameterized test covers both
/// the single-reactor seed topology and a 4-reactor server.
fn reactor_counts() -> Vec<usize> {
    match std::env::var("SITU_REACTORS").ok().and_then(|v| v.parse::<usize>().ok()) {
        Some(n) if n > 0 => vec![n],
        _ => vec![1, 4],
    }
}

fn start_n(engine: Engine, reactors: usize) -> DbServer {
    DbServer::start(ServerConfig {
        engine,
        with_models: false,
        conn_read_timeout: Duration::from_millis(50),
        reactors,
        ..Default::default()
    })
    .unwrap()
}

/// `reactors: 0` = auto, so the non-parameterized tests also follow the
/// `SITU_REACTORS` knob when the CI matrix sets it.
fn start(engine: Engine) -> DbServer {
    start_n(engine, 0)
}

fn t(v: Vec<f32>) -> Tensor {
    Tensor::from_f32(&[v.len()], v).unwrap()
}

fn get(key: &str) -> Request {
    Request::GetTensor { key: key.to_string() }
}

fn poll(key: &str, timeout_ms: u64) -> Request {
    Request::PollKeys {
        keys: vec![key.to_string()],
        timeout_ms,
        initial_us: 1_000,
        cap_us: 20_000,
    }
}

/// N tagged requests in flight on one socket, replies collected in REVERSE
/// send order: every reply must pair with its own request's tag, byte-exact
/// payloads, on both engines.
#[test]
fn tagged_replies_pair_by_tag_not_order() {
    for reactors in reactor_counts() {
        for engine in [Engine::Redis, Engine::KeyDb] {
            let server = start_n(engine, reactors);
            let mut c = Client::connect(server.addr).unwrap();
            let n = 32usize;
            for i in 0..n {
                c.put_tensor(&format!("k{i}"), &t(vec![i as f32; 8 + i])).unwrap();
            }
            let tags: Vec<u32> =
                (0..n).map(|i| c.send_tagged(&get(&format!("k{i}"))).unwrap()).collect();
            for (i, tag) in tags.iter().enumerate().rev() {
                match c.recv_tagged(*tag).unwrap() {
                    Response::Tensor(got) => {
                        assert_eq!(got, t(vec![i as f32; 8 + i]), "tag {tag} ↔ k{i}");
                    }
                    other => panic!("k{i}: expected tensor, got {other:?}"),
                }
            }
        }
    }
}

/// Mixed put/get/poll/batch requests interleaved on one socket — the full
/// opcode spread the multiplexer must keep straight.
#[test]
fn mixed_request_kinds_interleave() {
    for reactors in reactor_counts() {
        let server = start_n(Engine::Redis, reactors);
        let mut c = Client::connect(server.addr).unwrap();
        let put = Request::PutTensor { key: "a".into(), tensor: t(vec![1.0, 2.0]) };
        let batch = Request::Batch(vec![
            Request::PutTensor { key: "b".into(), tensor: t(vec![3.0]) },
            Request::Exists { key: "a".into() },
        ]);
        let t_put = c.send_tagged(&put).unwrap();
        let t_poll = c.send_tagged(&poll("a", 2_000)).unwrap();
        let t_batch = c.send_tagged(&batch).unwrap();
        let t_get = c.send_tagged(&get("a")).unwrap();

        // Collect out of send order on purpose.
        assert!(matches!(c.recv_tagged(t_put).unwrap(), Response::Ok));
        match c.recv_tagged(t_batch).unwrap() {
            Response::Batch(rs) => {
                assert!(matches!(rs[0], Response::Ok));
                assert!(matches!(rs[1], Response::Bool(true)));
            }
            other => panic!("expected batch reply, got {other:?}"),
        }
        assert!(matches!(c.recv_tagged(t_poll).unwrap(), Response::Bool(true)));
        match c.recv_tagged(t_get).unwrap() {
            Response::Tensor(got) => assert_eq!(got, t(vec![1.0, 2.0])),
            other => panic!("expected tensor, got {other:?}"),
        }
    }
}

/// The no-head-of-line-blocking proof: a parked poll on one socket must NOT
/// stall a later get on the SAME socket.  The get answers while the poll is
/// still waiting; producing the key then resolves the poll.
#[test]
fn parked_poll_does_not_block_same_socket() {
    for reactors in reactor_counts() {
        let server = start_n(Engine::Redis, reactors);
        let mut c = Client::connect(server.addr).unwrap();
        c.put_tensor("ready", &t(vec![9.0])).unwrap();

        let t_poll = c.send_tagged(&poll("late", 10_000)).unwrap();
        let t_get = c.send_tagged(&get("ready")).unwrap();

        // Under the old serial loop this would block ~10 s behind the poll.
        let started = Instant::now();
        match c.recv_tagged(t_get).unwrap() {
            Response::Tensor(got) => assert_eq!(got, t(vec![9.0])),
            other => panic!("expected tensor, got {other:?}"),
        }
        assert!(
            started.elapsed() < Duration::from_secs(2),
            "get stalled {:?} behind a parked poll",
            started.elapsed()
        );

        // The producer may land on a DIFFERENT reactor than the waiter:
        // the write-wakeup path goes through the shared store/hub, so
        // the parked poll must resolve regardless.
        let mut producer = Client::connect(server.addr).unwrap();
        producer.put_tensor("late", &t(vec![1.0])).unwrap();
        assert!(matches!(c.recv_tagged(t_poll).unwrap(), Response::Bool(true)));
    }
}

/// Write-triggered wakeup: a poll parked with a LONG backoff interval must
/// resolve within milliseconds of the satisfying put — strictly before the
/// next backoff probe would have fired — because `put_tensor` notifies the
/// poll hub directly instead of leaving the waiter to its probe clock.
#[test]
fn write_wakeup_beats_the_backoff_clock() {
    let server = start(Engine::KeyDb);
    let mut c = Client::connect(server.addr).unwrap();
    // initial == cap == 200 ms: after the immediate verification probe
    // misses, the next probe-clock chance is a full 200 ms away.
    let slow_poll = Request::PollKeys {
        keys: vec!["wk".to_string()],
        timeout_ms: 5_000,
        initial_us: 200_000,
        cap_us: 200_000,
    };
    let tag = c.send_tagged(&slow_poll).unwrap();
    std::thread::sleep(Duration::from_millis(50));

    let mut producer = Client::connect(server.addr).unwrap();
    let put_at = Instant::now();
    producer.put_tensor("wk", &t(vec![7.0])).unwrap();
    assert!(matches!(c.recv_tagged(tag).unwrap(), Response::Bool(true)));
    let latency = put_at.elapsed();
    assert!(
        latency < Duration::from_millis(150),
        "poll resolved {latency:?} after the put — backoff clock, not write wakeup"
    );
    assert!(
        server.poll_write_wakeups() >= 1,
        "write never reached the poll hub's waiter map"
    );

    // Probe-clock fallback still owns expiry: an absent key times out at
    // its own deadline even though no write ever wakes it.
    let started = Instant::now();
    let tag = c
        .send_tagged(&Request::PollKeys {
            keys: vec!["never".to_string()],
            timeout_ms: 300,
            initial_us: 50_000,
            cap_us: 100_000,
        })
        .unwrap();
    assert!(matches!(c.recv_tagged(tag).unwrap(), Response::Bool(false)));
    let elapsed = started.elapsed();
    assert!(elapsed >= Duration::from_millis(200), "expired early: {elapsed:?}");
    assert!(elapsed < Duration::from_secs(2), "overslept: {elapsed:?}");
}

/// Tagged interleaving stays byte-exact when every socket op may be delayed
/// by a seeded fault plan (delay-only: reordering pressure, no data loss).
#[test]
fn interleaving_byte_exact_under_seeded_delays() {
    let plan = Arc::new(FaultPlan::new(FaultConfig {
        seed: 42,
        delay_p: 0.3,
        delay: Duration::from_micros(300),
        ..FaultConfig::default()
    }));
    let server = DbServer::start(ServerConfig {
        engine: Engine::KeyDb,
        with_models: false,
        conn_read_timeout: Duration::from_millis(250),
        fault: Some(plan.clone()),
        ..Default::default()
    })
    .unwrap();
    let mut c = Client::connect(server.addr).unwrap();
    let n = 24usize;
    for round in 0..4 {
        let reqs: Vec<Request> = (0..n)
            .map(|i| Request::PutTensor {
                key: format!("r{round}i{i}"),
                tensor: t(vec![(round * n + i) as f32; 16]),
            })
            .collect();
        for r in c.call_pipelined(&reqs).unwrap() {
            assert!(matches!(r, Response::Ok));
        }
        let gets: Vec<Request> = (0..n).map(|i| get(&format!("r{round}i{i}"))).collect();
        for (i, r) in c.call_pipelined(&gets).unwrap().into_iter().enumerate() {
            match r {
                Response::Tensor(got) => {
                    assert_eq!(got, t(vec![(round * n + i) as f32; 16]));
                }
                other => panic!("round {round} i {i}: {other:?}"),
            }
        }
    }
    assert!(plan.counters().delayed_ops > 0, "plan never fired — test is vacuous");
}

/// Cross-reactor interleave: a fleet of connections lands across FOUR
/// reactors (SO_REUSEPORT hashing, or round-robin handoff where reuseport
/// is unavailable) while a seeded fault plan delays socket ops.  Every
/// connection's tagged replies must still pair by tag with byte-exact
/// payloads — reactor boundaries add no reordering or cross-talk.
#[test]
fn cross_reactor_interleaving_byte_exact_under_seeded_delays() {
    let plan = Arc::new(FaultPlan::new(FaultConfig {
        seed: 1999,
        delay_p: 0.25,
        delay: Duration::from_micros(300),
        ..FaultConfig::default()
    }));
    let server = DbServer::start(ServerConfig {
        engine: Engine::KeyDb,
        with_models: false,
        conn_read_timeout: Duration::from_millis(250),
        fault: Some(plan.clone()),
        reactors: 4,
        ..Default::default()
    })
    .unwrap();
    assert_eq!(server.reactors(), 4, "sharded topology requested");

    let mut clients: Vec<Client> =
        (0..8).map(|_| Client::connect(server.addr).unwrap()).collect();
    let val = |ci: usize, round: usize, i: usize| (ci * 1000 + round * 100 + i) as f32;
    for round in 0..3usize {
        // Phase 1: every client floods its reactor with tagged puts before
        // anyone collects, maximizing concurrent in-flight work.
        let put_tags: Vec<Vec<u32>> = clients
            .iter_mut()
            .enumerate()
            .map(|(ci, c)| {
                (0..12)
                    .map(|i| {
                        c.send_tagged(&Request::PutTensor {
                            key: format!("x{ci}r{round}i{i}"),
                            tensor: t(vec![val(ci, round, i); 16]),
                        })
                        .unwrap()
                    })
                    .collect()
            })
            .collect();
        for (ci, c) in clients.iter_mut().enumerate() {
            for tag in &put_tags[ci] {
                assert!(
                    matches!(c.recv_tagged(*tag).unwrap(), Response::Ok),
                    "client {ci} put tag {tag} failed"
                );
            }
        }
        // Phase 2: read everything back, collecting in REVERSE send order.
        let get_tags: Vec<Vec<u32>> = clients
            .iter_mut()
            .enumerate()
            .map(|(ci, c)| {
                (0..12)
                    .map(|i| c.send_tagged(&get(&format!("x{ci}r{round}i{i}"))).unwrap())
                    .collect()
            })
            .collect();
        for (ci, c) in clients.iter_mut().enumerate() {
            for (i, tag) in get_tags[ci].iter().enumerate().rev() {
                match c.recv_tagged(*tag).unwrap() {
                    Response::Tensor(got) => assert_eq!(
                        got,
                        t(vec![val(ci, round, i); 16]),
                        "client {ci} round {round} i {i}"
                    ),
                    other => panic!("client {ci} i {i}: expected tensor, got {other:?}"),
                }
            }
        }
    }
    assert!(plan.counters().delayed_ops > 0, "plan never fired — test is vacuous");
}

/// A scripted sever mid-conversation surfaces as a clean error on recv —
/// never a hang (the reactor closes the conn; the client sees EOF).
#[test]
fn severed_connection_errors_cleanly() {
    let plan = Arc::new(FaultPlan::new(FaultConfig {
        seed: 7,
        sever_after_ops: Some(40),
        ..FaultConfig::default()
    }));
    let server = DbServer::start(ServerConfig {
        engine: Engine::Redis,
        with_models: false,
        conn_read_timeout: Duration::from_millis(250),
        fault: Some(plan),
        ..Default::default()
    })
    .unwrap();
    let mut c =
        Client::connect_with(server.addr, Some(Duration::from_secs(5)), None).unwrap();
    let started = Instant::now();
    let mut failed = false;
    'outer: for round in 0..200 {
        let Ok(tag) = c.send_tagged(&get(&format!("missing{round}"))) else {
            failed = true;
            break 'outer;
        };
        if c.recv_tagged(tag).is_err() {
            failed = true;
            break 'outer;
        }
    }
    assert!(failed, "scripted sever never surfaced");
    assert!(started.elapsed() < Duration::from_secs(10), "sever turned into a hang");
}

/// Legacy untagged clients still round-trip against the multiplexed server,
/// and back-to-back untagged frames keep strict FIFO reply order (the
/// legacy ordering contract).
#[test]
fn legacy_untagged_clients_roundtrip_in_order() {
    for reactors in reactor_counts() {
        let server = start_n(Engine::Redis, reactors);

        // The plain Client API is itself an untagged (tag-0) peer.
        let mut c = Client::connect(server.addr).unwrap();
        c.put_tensor("legacy", &t(vec![4.0, 2.0])).unwrap();
        assert_eq!(c.get_tensor("legacy").unwrap(), t(vec![4.0, 2.0]));

        // Raw socket: two untagged frames written back-to-back, replies must
        // come back in request order (PutMeta's Ok before GetMeta's value).
        let mut sock = TcpStream::connect(server.addr).unwrap();
        let mut buf = Vec::new();
        Request::PutMeta { key: "step".into(), value: "17".into() }.encode(&mut buf);
        write_frame(&mut sock, &buf).unwrap();
        buf.clear();
        Request::GetMeta { key: "step".into() }.encode(&mut buf);
        write_frame(&mut sock, &buf).unwrap();

        let first = read_frame(&mut sock).unwrap().expect("server closed");
        assert!(matches!(Response::decode(&first).unwrap(), Response::Ok));
        let second = read_frame(&mut sock).unwrap().expect("server closed");
        match Response::decode(&second).unwrap() {
            Response::Meta(v) => assert_eq!(v, "17"),
            other => panic!("expected meta reply, got {other:?}"),
        }
        drop(sock);
    }
}

/// Regression for the batch-poll latency bug: a batch of polls on absent
/// keys must wait ≈ the MAX entry timeout (entries share the batch's
/// deadline clock), not the SUM of entry timeouts.
#[test]
fn batch_poll_waits_bounded_by_max_not_sum() {
    let server = start(Engine::Redis);
    let mut c = Client::connect(server.addr).unwrap();
    let entries: Vec<Request> = (0..3).map(|i| poll(&format!("absent{i}"), 500)).collect();
    let started = Instant::now();
    let resp = c.call(&Request::Batch(entries)).unwrap();
    let elapsed = started.elapsed();
    match resp {
        Response::Batch(rs) => {
            assert_eq!(rs.len(), 3);
            for r in &rs {
                assert!(matches!(r, Response::Bool(false)), "absent key polled true: {r:?}");
            }
        }
        other => panic!("expected batch reply, got {other:?}"),
    }
    assert!(elapsed >= Duration::from_millis(380), "polls returned early: {elapsed:?}");
    // Sum of timeouts would be 1500 ms; shared deadline keeps it ≈ 500 ms.
    assert!(elapsed < Duration::from_millis(1100), "batch polls summed timeouts: {elapsed:?}");
}

/// A bare (non-batch) poll still honours its own timeout through the parked
/// waiter path, and the client's poll_key maps the timeout to an error.
#[test]
fn bare_poll_timeout_preserved() {
    let server = start(Engine::Redis);
    let mut c = Client::connect(server.addr).unwrap();
    let cfg = PollConfig::new(
        Duration::from_millis(1),
        Duration::from_millis(10),
        Duration::from_millis(120),
    );
    let started = Instant::now();
    assert!(c.poll_key("never", &cfg).is_err());
    let elapsed = started.elapsed();
    assert!(elapsed >= Duration::from_millis(90), "timed out early: {elapsed:?}");
    assert!(elapsed < Duration::from_secs(2), "overslept: {elapsed:?}");
}

/// Idle connections cost nothing and don't delay shutdown: with a LONG
/// conn_read_timeout and a fleet of idle sockets, shutdown is signal-driven
/// and prompt (the old accept/read timeout ladder made this scale with the
/// configured timeouts).
#[test]
fn shutdown_with_idle_connections_is_prompt() {
    let mut server = DbServer::start(ServerConfig {
        engine: Engine::KeyDb,
        with_models: false,
        conn_read_timeout: Duration::from_secs(30),
        ..Default::default()
    })
    .unwrap();
    let mut idlers: Vec<Client> = (0..8).map(|_| Client::connect(server.addr).unwrap()).collect();
    // One of them does real work so the conns are demonstrably live.
    idlers[0].put_tensor("x", &t(vec![1.0])).unwrap();
    let started = Instant::now();
    server.shutdown();
    let elapsed = started.elapsed();
    assert!(elapsed < Duration::from_secs(1), "shutdown took {elapsed:?} with idle conns");
    drop(idlers);
}
