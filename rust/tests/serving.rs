//! Integration: the versioned serving subsystem over TCP — model registry
//! hot-swaps racing in-flight inference, adaptive micro-batching, pinned
//! versions, cluster publish resilience, and the serving wire ops.
//!
//! Everything here uses the native interpreter backend (`situ-native v1`
//! texts), so no PJRT artifacts are required.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Barrier};
use std::time::Duration;

use situ::ai::{BatcherConfig, ModelRuntime};
use situ::client::{Client, ClusterClient, DataStore};
use situ::db::{DbServer, ServerConfig};
use situ::proto::Device;
use situ::runtime::Executor;
use situ::tensor::Tensor;

fn affine_text(offset: f64) -> String {
    format!("situ-native v1\naffine 1 {offset}\n")
}

#[test]
fn put_model_replies_versions_and_wire_ops_report_them() {
    let server = DbServer::start(ServerConfig::default()).unwrap();
    let mut c = Client::connect(server.addr).unwrap();

    assert_eq!(c.put_model("m", &affine_text(1.0)).unwrap(), 1);
    assert_eq!(c.put_model("m", &affine_text(2.0)).unwrap(), 2);
    assert_eq!(c.put_model("other", &affine_text(9.0)).unwrap(), 1);

    let entries = c.list_models().unwrap();
    assert_eq!(entries.len(), 2);
    let m = entries.iter().find(|e| e.key == "m").unwrap();
    assert_eq!((m.live_version, m.n_versions, m.swaps), (2, 2, 1));

    let info = c.info().unwrap();
    assert_eq!(info.models, 2, "distinct live keys");
    assert_eq!(info.model_swaps, 1);

    // Device stats appear once something actually executes.
    c.put_tensor("x", &Tensor::from_f32(&[2], vec![1.0, 2.0]).unwrap()).unwrap();
    c.run_model("m", &["x".into()], &["y".into()], Device::Gpu(2)).unwrap();
    let stats = c.model_stats().unwrap();
    let gpu2 = stats
        .iter()
        .find(|s| s.device == Device::Gpu(2))
        .expect("gpu2 row present after execution");
    assert!(gpu2.executions >= 1);
    assert!(gpu2.eval_count >= 1);
}

#[test]
fn pinned_versions_survive_swaps() {
    let server = DbServer::start(ServerConfig::default()).unwrap();
    let mut c = Client::connect(server.addr).unwrap();
    c.put_model("m", &affine_text(10.0)).unwrap();
    c.put_model("m", &affine_text(20.0)).unwrap();
    c.put_tensor("x", &Tensor::from_f32(&[1], vec![1.0]).unwrap()).unwrap();

    c.run_model_version("m", 1, &["x".into()], &["y1".into()], Device::Cpu).unwrap();
    assert_eq!(c.get_tensor("y1").unwrap().to_f32().unwrap(), vec![11.0]);

    c.run_model("m", &["x".into()], &["y".into()], Device::Cpu).unwrap();
    assert_eq!(c.get_tensor("y").unwrap().to_f32().unwrap(), vec![21.0]);

    let err = c
        .run_model_version("m", 3, &["x".into()], &["z".into()], Device::Cpu)
        .unwrap_err();
    assert!(err.to_string().contains("model not found"), "{err}");
}

/// The acceptance gate: a publisher hot-swaps new versions while clients
/// hammer the live model.  Every call must succeed and every output must be
/// consistent with exactly one published version — never torn between two.
#[test]
fn hot_swap_race_never_tears_or_fails() {
    const WORKERS: usize = 4;
    const ITERS: usize = 25;
    const LAST_VERSION: u64 = 8;

    let server = DbServer::start(ServerConfig::default()).unwrap();
    let addr = server.addr;
    {
        let mut c = Client::connect(addr).unwrap();
        c.put_model("m", &affine_text(1.0)).unwrap();
    }

    let done = Arc::new(AtomicBool::new(false));
    let publisher = {
        let done = Arc::clone(&done);
        std::thread::spawn(move || {
            let mut c = Client::connect(addr).unwrap();
            for v in 2..=LAST_VERSION {
                let got = c.put_model("m", &affine_text(v as f64)).unwrap();
                assert_eq!(got, v, "publishes serialize, versions stay monotonic");
                std::thread::sleep(Duration::from_millis(3));
            }
            done.store(true, Ordering::Relaxed);
        })
    };

    let mut workers = Vec::new();
    for w in 0..WORKERS {
        workers.push(std::thread::spawn(move || {
            let mut c = Client::connect(addr).unwrap();
            for it in 0..ITERS {
                let base = (w * 1000 + it) as f32;
                let x: Vec<f32> = (0..8).map(|i| base + i as f32).collect();
                let ik = format!("in_{w}_{it}");
                let ok = format!("out_{w}_{it}");
                c.put_tensor(&ik, &Tensor::from_f32(&[8], x.clone()).unwrap()).unwrap();
                c.run_model("m", &[ik], &[ok.clone()], Device::Gpu(w % 4)).unwrap();
                let y = c.get_tensor(&ok).unwrap().to_f32().unwrap();
                // Recover the version from element 0, then demand every
                // element agree with that same version.  All values here
                // are small integers, exact in f32.
                let v0 = y[0] - x[0];
                assert!(
                    (1.0..=LAST_VERSION as f32).contains(&v0) && v0.fract() == 0.0,
                    "output from a version never published: offset {v0}"
                );
                for (i, (yi, xi)) in y.iter().zip(&x).enumerate() {
                    assert_eq!(
                        yi - xi,
                        v0,
                        "torn output at element {i}: versions mixed within one call"
                    );
                }
            }
        }));
    }
    for h in workers {
        h.join().expect("no run_model call may fail during hot swaps");
    }
    publisher.join().unwrap();
    assert!(done.load(Ordering::Relaxed));

    let mut c = Client::connect(addr).unwrap();
    let entries = c.list_models().unwrap();
    assert_eq!(entries[0].live_version, LAST_VERSION);
    assert_eq!(entries[0].swaps, LAST_VERSION - 1);
    assert_eq!(
        entries[0].executions,
        (WORKERS * ITERS) as u64,
        "every call executed exactly once somewhere"
    );
    assert_eq!(c.info().unwrap().model_swaps, LAST_VERSION - 1);
}

/// Concurrent same-(key, version, device) calls coalesce into stacked
/// executions behind the batching window, and every caller still gets its
/// own correct slice back.
#[test]
fn concurrent_calls_coalesce_into_batches() {
    const CALLERS: usize = 8;
    let exec = Executor::new().unwrap();
    let models = ModelRuntime::with_batcher(
        exec,
        BatcherConfig {
            window: Duration::from_millis(80),
            max_batch: 32,
            // A huge burst threshold makes every arrival after the first
            // count as a burst — deterministic coalescing in a test.
            adapt_arrival: Duration::from_secs(600),
        },
    );
    let server =
        DbServer::start_with(ServerConfig::default(), Some(Arc::new(models))).unwrap();
    let addr = server.addr;
    {
        let mut c = Client::connect(addr).unwrap();
        c.put_model("m", &affine_text(5.0)).unwrap();
        // Prime the lane so the storm below arrives as a burst.
        c.put_tensor("warm", &Tensor::from_f32(&[1], vec![0.0]).unwrap()).unwrap();
        c.run_model("m", &["warm".into()], &["warm_out".into()], Device::Gpu(0)).unwrap();
    }

    let barrier = Arc::new(Barrier::new(CALLERS));
    let mut handles = Vec::new();
    for w in 0..CALLERS {
        let barrier = Arc::clone(&barrier);
        handles.push(std::thread::spawn(move || {
            let mut c = Client::connect(addr).unwrap();
            let x = vec![w as f32, w as f32 + 0.5];
            let ik = format!("b_in_{w}");
            let ok = format!("b_out_{w}");
            c.put_tensor(&ik, &Tensor::from_f32(&[2], x.clone()).unwrap()).unwrap();
            barrier.wait();
            c.run_model("m", &[ik], &[ok.clone()], Device::Gpu(0)).unwrap();
            let y = c.get_tensor(&ok).unwrap().to_f32().unwrap();
            assert_eq!(y, vec![x[0] + 5.0, x[1] + 5.0], "de-stacked slice");
        }));
    }
    for h in handles {
        h.join().unwrap();
    }

    let mut c = Client::connect(addr).unwrap();
    let info = c.info().unwrap();
    assert!(info.batches >= 1, "storm produced no coalesced batch");
    assert!(
        info.batched_requests >= 2,
        "coalesced batches must cover >1 request (got {})",
        info.batched_requests
    );
    // Stacking reduces backend executions below the request count.
    let entries = c.list_models().unwrap();
    let total_requests = 1 + CALLERS as u64; // warmup + storm
    assert!(
        entries[0].executions < total_requests,
        "stacking saved executions: {} backend runs for {} requests",
        entries[0].executions,
        total_requests
    );
}

#[test]
fn cluster_publish_degrades_partially_and_keeps_serving() {
    let mut servers: Vec<DbServer> =
        (0..3).map(|_| DbServer::start(ServerConfig::default()).unwrap()).collect();
    let addrs: Vec<_> = servers.iter().map(|s| s.addr).collect();
    let mut c = ClusterClient::connect(&addrs).unwrap();

    assert_eq!(c.put_model("m", &affine_text(3.0)).unwrap(), 1);
    assert!(c.shard_errors().is_empty());

    // Inference routes through the cluster too.
    c.put_tensor("cx", &Tensor::from_f32(&[2], vec![1.0, 2.0]).unwrap()).unwrap();
    c.run_model("m", &["cx".into()], &["cy".into()], Device::Gpu(1)).unwrap();
    assert_eq!(c.get_tensor("cy").unwrap().to_f32().unwrap(), vec![4.0, 5.0]);

    // Kill one shard: publishing degrades instead of failing, reports the
    // dead shard, and counts the partial op.
    servers[1].simulate_crash();
    let v2 = c.put_model("m", &affine_text(4.0)).unwrap();
    assert_eq!(v2, 2, "surviving shards advanced to version 2");
    assert!(!c.shard_errors().is_empty(), "dead shard reported per-shard");
    let info = c.info().unwrap();
    assert!(info.degraded_ops >= 1, "partial publish counted as degraded");

    // The merged registry view reflects the surviving shards.
    let entries = c.list_models().unwrap();
    assert_eq!(entries.len(), 1);
    assert_eq!(entries[0].live_version, 2);
}

#[test]
fn serving_ops_without_runtime_are_empty_not_errors() {
    let server =
        DbServer::start(ServerConfig { with_models: false, ..Default::default() }).unwrap();
    let mut c = Client::connect(server.addr).unwrap();
    assert!(c.list_models().unwrap().is_empty());
    assert!(c.model_stats().unwrap().is_empty());
    let err = c.put_model("m", &affine_text(1.0)).unwrap_err();
    assert!(err.to_string().contains("disabled"), "{err}");
}
