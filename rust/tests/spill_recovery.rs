//! Spill-to-disk cold tier: corruption / crash-recovery test battery.
//!
//! The durability claims of `db::spill` are earned here, not asserted:
//!
//! * a property test mutates valid segments — truncation anywhere, length
//!   field smashes, payload bitflips — and replay must always yield a clean
//!   `Err` or the surviving record *prefix*, never a panic, hang, or torn
//!   tensor;
//! * a crash-recovery test kills a writer mid-append (torn final record),
//!   reopens the directory, and proves replay returns exactly the complete
//!   records in order while the resumed writer appends without clobbering
//!   them;
//! * TCP-level tests prove evicted generations are recoverable byte-exact
//!   through `ColdGet`/`ColdList`, that `DataLoader::gather_window` falls
//!   back to the cold tier transparently, and that rotation under tiny
//!   segments (CI sets `SITU_SPILL_SEGMENT_BYTES`) keeps every record
//!   reachable.

use std::path::PathBuf;
use std::time::Duration;

use situ::client::{tensor_key, Client, DataStore};
use situ::db::spill::{replay_segment, SpillWriter};
use situ::db::{DbServer, Engine, RetentionConfig, ServerConfig, SpillConfig};
use situ::error::Error;
use situ::ml::DataLoader;
use situ::tensor::Tensor;
use situ::util::propcheck::{check, Gen};

fn tmp_dir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("situ_spillrec_{}_{name}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn t(vals: Vec<f32>) -> Tensor {
    Tensor::from_f32(&[vals.len()], vals).unwrap()
}

fn start_spill_server(window: u64, spill: SpillConfig) -> DbServer {
    DbServer::start(ServerConfig {
        engine: Engine::KeyDb,
        with_models: false,
        retention: RetentionConfig::windowed(window, 0),
        spill: Some(spill),
        conn_read_timeout: Duration::from_millis(50),
        ..Default::default()
    })
    .unwrap()
}

/// Newest `.spill` segment file under a spill directory (recursive).
fn newest_segment(dir: &PathBuf) -> PathBuf {
    fn walk(dir: &PathBuf, out: &mut Vec<PathBuf>) {
        for e in std::fs::read_dir(dir).unwrap() {
            let p = e.unwrap().path();
            if p.is_dir() {
                walk(&p, out);
            } else if p.extension().and_then(|x| x.to_str()) == Some("spill") {
                out.push(p);
            }
        }
    }
    let mut segs = Vec::new();
    walk(dir, &mut segs);
    segs.sort();
    segs.pop().expect("at least one segment")
}

#[test]
fn prop_corrupted_segment_replays_as_clean_prefix() {
    // Build one valid segment per case, then mutate it three ways.  Replay
    // must never panic or hang: either a clean Err (unparseable file) or
    // the surviving prefix of the original records, each byte-exact.
    check("spill corruption battery", 60, |g: &mut Gen| {
        let case = g.u64();
        let dir = tmp_dir(&format!("prop{case}"));
        let group = dir.join("g");
        let n_records = g.usize_in(1..=6);
        let originals: Vec<(String, Tensor)> = (0..n_records)
            .map(|i| {
                let len = g.usize_in(1..=32);
                let vals: Vec<f32> = (0..len).map(|_| g.normal_f32()).collect();
                (format!("f_rank0_step{i}"), t(vals))
            })
            .collect();
        let path = {
            let (mut w, _) = SpillWriter::open(&group, 1 << 20, |_, _| {}).unwrap();
            for (k, tensor) in &originals {
                w.append(k, tensor).unwrap();
            }
            w.flush().unwrap();
            (**w.active_segment()).clone()
        };
        let pristine = std::fs::read(&path).unwrap();

        let mut mutated = pristine.clone();
        match g.usize_in(0..=2) {
            0 => {
                // Truncation anywhere, including inside the header.
                let cut = g.usize_in(0..=mutated.len() - 1);
                mutated.truncate(cut);
            }
            1 => {
                // Length-field smash: an extreme u32 at a random offset.
                let i = g.usize_in(0..=mutated.len() - 1);
                let huge = if g.bool() { u32::MAX } else { u32::MAX / 2 };
                for (o, b) in huge.to_le_bytes().iter().enumerate() {
                    if i + o < mutated.len() {
                        mutated[i + o] = *b;
                    }
                }
            }
            _ => {
                // Payload / header bitflips.
                for _ in 0..g.usize_in(1..=8) {
                    let i = g.usize_in(0..=mutated.len() - 1);
                    mutated[i] ^= 1 << g.usize_in(0..=7);
                }
            }
        }
        std::fs::write(&path, &mutated).unwrap();

        match replay_segment(&path) {
            Err(_) => {} // clean refusal (e.g. smashed segment header)
            Ok(replay) => {
                assert!(
                    replay.records.len() <= originals.len(),
                    "replay invented records"
                );
                for (rec, (key, tensor)) in replay.records.iter().zip(&originals) {
                    assert_eq!(&rec.key, key, "prefix keys in order");
                    assert_eq!(&rec.tensor, tensor, "prefix payloads byte-exact");
                }
            }
        }
        let _ = std::fs::remove_dir_all(&dir);
    });
}

#[test]
fn crash_mid_append_recovers_and_resumes_without_clobbering() {
    // End-to-end crash simulation through the Store: spill three retired
    // generations, "crash" by appending half a record's worth of garbage
    // (a writer killed mid-append), then reopen the directory with a fresh
    // store.  Replay must surface exactly the complete records, and the
    // resumed writer must append after them without clobbering.
    let dir = tmp_dir("crash");
    {
        let server = start_spill_server(1, SpillConfig::new(&dir));
        let mut c = Client::connect(server.addr).unwrap();
        for step in 0..4u64 {
            c.put_tensor(&tensor_key("cr", 0, step), &t(vec![step as f32; 16])).unwrap();
        }
        let info = c.info().unwrap(); // INFO syncs the spill writer
        assert_eq!(info.spilled_keys, 3);
        server.store().set_spill(None).unwrap(); // clean close of the log
    }
    let seg = newest_segment(&dir);
    {
        use std::io::Write;
        let mut f = std::fs::OpenOptions::new().append(true).open(&seg).unwrap();
        // A torn half-record: plausible header bytes, missing body.
        f.write_all(&[0x53, 0x50, 0x53, 0x31, 0xEE, 0x00, 0x00]).unwrap();
    }

    let server = start_spill_server(1, SpillConfig::new(&dir));
    let mut c = Client::connect(server.addr).unwrap();
    assert_eq!(
        c.cold_list("cr_").unwrap(),
        vec![
            tensor_key("cr", 0, 0),
            tensor_key("cr", 0, 1),
            tensor_key("cr", 0, 2)
        ],
        "exactly the complete records survive"
    );
    for step in 0..3u64 {
        let back = c.cold_get(&tensor_key("cr", 0, step)).unwrap();
        assert_eq!(back.to_f32().unwrap(), vec![step as f32; 16], "byte-exact after crash");
    }
    // The resumed writer appends new retirements after the survivors: the
    // fresh store holds generation 4, and publishing 5 retires (spills) it.
    for step in 4..6u64 {
        c.put_tensor(&tensor_key("cr", 0, step), &t(vec![step as f32; 16])).unwrap();
    }
    let cold = c.cold_list("cr_").unwrap();
    assert_eq!(cold.len(), 4, "survivors 0-2 plus newly-retired 4: {cold:?}");
    for step in [0u64, 1, 2, 4] {
        let back = c.cold_get(&tensor_key("cr", 0, step)).unwrap();
        assert_eq!(back.to_f32().unwrap(), vec![step as f32; 16]);
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn evicted_generations_are_cold_readable_over_tcp() {
    let dir = tmp_dir("tcp");
    let server = start_spill_server(2, SpillConfig::new(&dir));
    let mut c = Client::connect(server.addr).unwrap();
    let ranks = 2usize;
    for step in 0..6u64 {
        for r in 0..ranks {
            let val = (step * 10 + r as u64) as f32;
            c.put_tensor(&tensor_key("f", r, step), &t(vec![val; 8])).unwrap();
        }
    }
    // Steps 0..3 were retired by the window; every key replays byte-exact.
    for step in 0..4u64 {
        for r in 0..ranks {
            let back = c.cold_get(&tensor_key("f", r, step)).unwrap();
            let val = (step * 10 + r as u64) as f32;
            assert_eq!(back.to_f32().unwrap(), vec![val; 8], "step {step} rank {r}");
        }
    }
    // Resident generations are hot-only; cold misses are clean NotFound.
    assert!(matches!(
        c.cold_get(&tensor_key("f", 0, 5)),
        Err(Error::KeyNotFound(_))
    ));
    assert!(matches!(c.cold_get("never_existed"), Err(Error::KeyNotFound(_))));
    let cold = c.cold_list("f_").unwrap();
    assert_eq!(cold.len(), 4 * ranks);
    assert!(cold.windows(2).all(|w| w[0] < w[1]), "sorted");
    // Counters: everything evicted was spilled, and the hits were counted.
    let info = c.info().unwrap();
    assert_eq!(info.spilled_keys, info.evicted_keys);
    assert_eq!(info.spilled_keys, 4 * ranks as u64);
    assert_eq!(info.spilled_bytes, info.evicted_bytes);
    assert!(info.spill_segments >= 1);
    assert_eq!(info.cold_hits, 4 * ranks as u64);
    assert_eq!(info.spill_lost_keys, 0, "every victim became durable");
    let fp = info.fields.iter().find(|f| f.field == "f").expect("field pressure");
    assert_eq!(fp.spilled_keys, 4 * ranks as u64);
    assert_eq!(fp.spilled_bytes, info.spilled_bytes);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn gather_window_falls_back_to_the_cold_tier() {
    let dir = tmp_dir("loader");
    let ranks = 2usize;
    let publish = |c: &mut Client| {
        for step in 0..5u64 {
            for r in 0..ranks {
                let val = (step * 10 + r as u64) as f32;
                c.put_tensor(&tensor_key("w", r, step), &t(vec![val; 8])).unwrap();
            }
        }
    };
    // With spill: the whole 5-generation window comes back even though
    // only the newest generation is still resident.
    let server = start_spill_server(1, SpillConfig::new(&dir));
    let mut c = Client::connect(server.addr).unwrap();
    publish(&mut c);
    assert_eq!(server.store().list_keys("w_").len(), ranks, "one resident generation");
    let mut dl = DataLoader::new(c, (0..ranks).collect(), "w", 1);
    let got = dl.gather_window(4, 5).unwrap();
    assert_eq!(got.len(), 5 * ranks, "cold fallback completed the window");
    for (i, tensor) in got.iter().enumerate() {
        let (step, r) = ((i / ranks) as u64, (i % ranks) as u64);
        assert_eq!(tensor.to_f32().unwrap(), vec![(step * 10 + r) as f32; 8]);
    }
    assert_eq!(dl.gens_cold(), 4, "four generations recovered from disk");
    assert_eq!(dl.gens_skipped(), 0);

    // Without spill: the retired generations are skipped, as before.
    let bare = DbServer::start(ServerConfig {
        engine: Engine::KeyDb,
        with_models: false,
        retention: RetentionConfig::windowed(1, 0),
        conn_read_timeout: Duration::from_millis(50),
        ..Default::default()
    })
    .unwrap();
    let mut c = Client::connect(bare.addr).unwrap();
    publish(&mut c);
    let mut dl = DataLoader::new(c, (0..ranks).collect(), "w", 1);
    let got = dl.gather_window(4, 5).unwrap();
    assert_eq!(got.len(), ranks, "only the resident generation");
    assert_eq!(dl.gens_skipped(), 4);
    assert_eq!(dl.gens_cold(), 0);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn tiny_segments_rotate_without_losing_records() {
    // Explicit tiny segment size (CI additionally runs the whole file with
    // SITU_SPILL_SEGMENT_BYTES=4096): every record must survive rotation
    // and the cold byte cap must only ever drop whole sealed segments.
    let dir = tmp_dir("tiny");
    let spill = SpillConfig { dir: dir.clone(), max_bytes: 0, segment_bytes: 128 };
    let server = start_spill_server(1, spill);
    let mut c = Client::connect(server.addr).unwrap();
    for step in 0..8u64 {
        c.put_tensor(&tensor_key("rot", 0, step), &t(vec![step as f32; 16])).unwrap();
    }
    let info = c.info().unwrap();
    assert_eq!(info.spilled_keys, 7);
    assert!(info.spill_segments > 1, "rotation happened: {}", info.spill_segments);
    for step in 0..7u64 {
        let back = c.cold_get(&tensor_key("rot", 0, step)).unwrap();
        assert_eq!(back.to_f32().unwrap(), vec![step as f32; 16]);
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn cold_byte_cap_drops_oldest_sealed_segments_only() {
    let dir = tmp_dir("cap");
    // ~100-byte records, 128-byte segments (one record per segment), and a
    // cap of ~4 segments: early records age out, newest stay readable.
    let spill = SpillConfig { dir: dir.clone(), max_bytes: 600, segment_bytes: 128 };
    let server = start_spill_server(1, spill);
    let mut c = Client::connect(server.addr).unwrap();
    for step in 0..12u64 {
        c.put_tensor(&tensor_key("aged", 0, step), &t(vec![step as f32; 16])).unwrap();
    }
    let info = c.info().unwrap();
    assert_eq!(info.spilled_keys, 11, "every retirement was appended");
    let cold = c.cold_list("aged_").unwrap();
    assert!(
        cold.len() < 11,
        "the cap dropped old segments: {} keys resident",
        cold.len()
    );
    // The newest spilled generation always survives (its segment is the
    // youngest), and everything still listed reads back byte-exact.
    assert!(cold.contains(&tensor_key("aged", 0, 10)));
    for key in &cold {
        let step: f32 = c.cold_get(key).unwrap().to_f32().unwrap()[0];
        assert!((0.0..11.0).contains(&step));
    }
    // Dropped keys miss cleanly.
    for step in 0..11u64 {
        let key = tensor_key("aged", 0, step);
        if !cold.contains(&key) {
            assert!(matches!(c.cold_get(&key), Err(Error::KeyNotFound(_))));
        }
    }
    let _ = std::fs::remove_dir_all(&dir);
}
