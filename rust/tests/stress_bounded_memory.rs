//! Bounded-memory stress and long-run integration tests.
//!
//! * Concurrency stress: N producer threads appending step generations and
//!   M windowed consumers gathering against one server **while eviction
//!   runs**, asserting no torn reads, clean `NotFound` on evicted keys, and
//!   exact byte accounting afterwards.
//! * Long run: a driver-launched deployment under a byte cap holds store
//!   bytes at a flat steady state over ≥ 200 producer steps, while the
//!   windowed gather returns byte-identical samples to an unbounded
//!   append-mode store over the same retained window.
//!
//! `SITU_STRESS_STEPS` bounds the stress iteration count (CI sets a small
//! value, mirroring `SITU_BENCH_SMOKE`).

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use situ::client::{stable_key, tensor_key, Client, DataStore, PollConfig};
use situ::config::RunConfig;
use situ::db::{DbServer, Engine, RetentionConfig, ServerConfig};
use situ::error::Error;
use situ::ml::DataLoader;
use situ::orchestrator::driver::Driver;
use situ::tensor::Tensor;

fn stress_steps(default_steps: u64) -> u64 {
    std::env::var("SITU_STRESS_STEPS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(default_steps)
        .max(10)
}

fn t_const(v: f32, n: usize) -> Tensor {
    Tensor::from_f32(&[n], vec![v; n]).unwrap()
}

#[test]
fn eviction_under_concurrent_producers_and_consumers() {
    let steps = stress_steps(120);
    let n_fields = 3usize;
    let ranks = 2usize;
    let elems = 256usize;
    let payload = (elems * 4) as u64;
    let window = 4u64;
    // Room for every field's window plus two generations of slack, so the
    // byte cap is armed without ever starving producers into Busy.
    let cap = (window + 2) * (n_fields * ranks) as u64 * payload;

    let server = DbServer::start(ServerConfig {
        engine: Engine::KeyDb,
        with_models: false,
        retention: RetentionConfig::windowed(window, cap),
        conn_read_timeout: Duration::from_millis(50),
        ..Default::default()
    })
    .unwrap();
    let addr = server.addr;
    let stop = Arc::new(AtomicBool::new(false));

    let mut producers = Vec::new();
    for f in 0..n_fields {
        producers.push(std::thread::spawn(move || {
            let mut c = Client::connect_retry(addr, 20, Duration::from_millis(10)).unwrap();
            for step in 0..steps {
                for r in 0..ranks {
                    let key = tensor_key(&format!("sf{f}"), r, step);
                    c.put_tensor(&key, &t_const(step as f32, elems)).unwrap();
                }
                c.put_meta(&format!("sf{f}_latest"), &step.to_string()).unwrap();
            }
        }));
    }

    let mut consumers = Vec::new();
    for f in 0..n_fields {
        let stop = Arc::clone(&stop);
        consumers.push(std::thread::spawn(move || {
            let client = Client::connect_retry(addr, 20, Duration::from_millis(10)).unwrap();
            let mut dl =
                DataLoader::new(client, (0..ranks).collect(), &format!("sf{f}"), 7 + f as u64);
            let mut gathered = 0u64;
            while !stop.load(Ordering::Relaxed) {
                let Some(latest) = dl.client.get_meta(&format!("sf{f}_latest")).unwrap() else {
                    std::thread::yield_now();
                    continue;
                };
                let latest: u64 = latest.parse().unwrap();
                match dl.gather_window(latest, window) {
                    Ok(samples) => {
                        gathered += samples.len() as u64;
                        for s in &samples {
                            // Every tensor was published with a constant
                            // payload; a mixed buffer would be a torn read.
                            let v = s.to_f32().unwrap();
                            let first = v[0];
                            assert!(
                                v.iter().all(|&x| x == first),
                                "torn read in field sf{f}: {first} vs mix"
                            );
                        }
                    }
                    // The producer ran ahead and the whole requested window
                    // was retired between the meta read and the gather —
                    // a clean NotFound, never a wedge or a partial tensor.
                    Err(Error::KeyNotFound(_)) => {}
                    Err(e) => panic!("consumer sf{f} failed: {e}"),
                }
            }
            gathered
        }));
    }

    for p in producers {
        p.join().unwrap();
    }
    stop.store(true, Ordering::Relaxed);
    let mut total_gathered = 0u64;
    for c in consumers {
        total_gathered += c.join().unwrap();
    }
    assert!(total_gathered > 0, "consumers made progress");

    // Post-mortem consistency through the in-process store handle.
    let store = server.store();
    let counters = &store.counters;
    assert!(store.n_bytes() <= cap, "cap respected: {} > {cap}", store.n_bytes());
    assert!(store.high_water_bytes() >= store.n_bytes());
    let resident: u64 = store
        .list_keys("")
        .iter()
        .map(|k| store.get_tensor(k).unwrap().nbytes() as u64)
        .sum();
    assert_eq!(store.n_bytes(), resident, "byte accounting drift after eviction");
    // Steady state: each field retains exactly its window of generations.
    for f in 0..n_fields {
        assert_eq!(
            store.list_keys(&format!("sf{f}_rank")).len() as u64,
            window * ranks as u64,
            "field sf{f} not windowed"
        );
    }
    let evicted_keys = counters.evicted_keys.load(Ordering::Relaxed);
    let evicted_bytes = counters.evicted_bytes.load(Ordering::Relaxed);
    assert_eq!(
        evicted_keys,
        (steps - window) * (n_fields * ranks) as u64,
        "every generation beyond the window was retired exactly once"
    );
    assert_eq!(evicted_bytes, evicted_keys * payload, "uniform payloads");
    assert_eq!(counters.busy_rejections.load(Ordering::Relaxed), 0, "cap never starved puts");

    // Evicted keys stay cleanly absent: a bounded poll times out rather
    // than wedging, and exists() says no.
    let mut c = Client::connect(addr).unwrap();
    let old_key = tensor_key("sf0", 0, 0);
    assert!(!c.exists(&old_key).unwrap());
    assert!(matches!(
        c.poll_keys(
            &[old_key],
            &PollConfig::new(
                Duration::from_millis(1),
                Duration::from_millis(5),
                Duration::from_millis(50),
            )
        ),
        Err(Error::Timeout(_))
    ));
}

#[test]
fn long_driver_run_holds_flat_memory_under_cap() {
    // Acceptance: ≥ 200 producer steps under a byte cap, store bytes flat
    // at steady state, windowed gather equivalent to append-mode on the
    // retained window.  The deployment goes through the Driver so the
    // retention config is exercised end to end (RunConfig → plan → server).
    let steps = 220u64;
    let ranks = 3usize;
    let elems = 256usize;
    let payload = (elems * 4) as u64;
    let window = 6u64;
    let cap = (window + 1) * ranks as u64 * payload;

    let mut run_cfg = RunConfig::default();
    run_cfg.nodes = 1;
    run_cfg.ranks_per_node = ranks;
    run_cfg.retention_window = window;
    run_cfg.db_max_bytes = cap;
    let mut driver = Driver::launch(&run_cfg, false).unwrap();
    let addr = driver.primary_addr();
    assert_eq!(
        driver.servers[0].store().retention(),
        RetentionConfig::windowed(window, cap),
        "driver threads the retention config into every server"
    );

    // Unbounded reference store fed identical data (the append-mode
    // baseline the windowed run must match on the retained window).
    let reference = DbServer::start(ServerConfig {
        engine: Engine::Redis,
        with_models: false,
        ..Default::default()
    })
    .unwrap();

    let mut c = Client::connect(addr).unwrap();
    let mut rc = Client::connect(reference.addr).unwrap();
    let mut series: Vec<u64> = Vec::with_capacity(steps as usize);
    for step in 0..steps {
        for r in 0..ranks {
            let snap = t_const((step * ranks as u64 + r as u64) as f32, elems);
            c.put_tensor(&tensor_key("field", r, step), &snap).unwrap();
            rc.put_tensor(&tensor_key("field", r, step), &snap).unwrap();
        }
        c.put_meta("latest_step", &step.to_string()).unwrap();
        series.push(driver.servers[0].store().n_bytes());
    }

    // Flat steady state: once the window has filled, resident bytes are
    // *exactly* constant — today's unbounded code grows linearly instead.
    let steady = &series[window as usize..];
    let mx = *steady.iter().max().unwrap();
    let mn = *steady.iter().min().unwrap();
    assert!(mx <= cap, "cap violated: {mx} > {cap}");
    assert_eq!(mx, mn, "steady-state bytes not flat: {mn}..{mx}");
    assert_eq!(mx, window * ranks as u64 * payload, "exactly the window resident");
    let unbounded = reference.store().n_bytes();
    assert_eq!(unbounded, steps * ranks as u64 * payload, "baseline grew linearly");

    // Windowed trainer-side equivalence: the bounded store serves the same
    // retained window, byte for byte, as the unbounded append store — so a
    // trainer consuming the window makes identical per-epoch progress.
    let latest = steps - 1;
    let mut dl = DataLoader::new(c, (0..ranks).collect(), "field", 11);
    dl.wait_for_step(latest, &PollConfig::default()).unwrap();
    let windowed = dl.gather_window(latest, window).unwrap();
    let mut rdl = DataLoader::new(rc, (0..ranks).collect(), "field", 11);
    let append = rdl.gather_window(latest, window).unwrap();
    assert_eq!(windowed.len(), window as usize * ranks);
    assert_eq!(windowed, append, "retained window identical to append-mode");

    driver.shutdown();
}

#[test]
fn spill_replay_equivalence_on_full_history() {
    // The cold-tier acceptance property: with a spill directory set, a
    // windowed byte-capped run ends with resident bytes under the cap AND
    // every retired generation replayable from the cold tier byte-exact —
    // windowed ≡ append on the retained window, and windowed+spill ≡
    // append on the FULL history.  The deployment goes through the Driver
    // so the spill config is exercised end to end (RunConfig --spill-dir →
    // DeploymentPlan → ServerConfig).
    let steps = stress_steps(60);
    let ranks = 2usize;
    let elems = 128usize;
    let payload = (elems * 4) as u64;
    let window = 4u64;
    let cap = (window + 1) * ranks as u64 * payload;
    let spill_base = std::env::temp_dir()
        .join(format!("situ_stress_spill_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&spill_base);

    let mut run_cfg = RunConfig::default();
    run_cfg.nodes = 1;
    run_cfg.ranks_per_node = ranks;
    run_cfg.retention_window = window;
    run_cfg.db_max_bytes = cap;
    run_cfg.spill_dir = Some(spill_base.display().to_string());
    let mut driver = Driver::launch(&run_cfg, false).unwrap();
    let addr = driver.primary_addr();

    // Unbounded append-mode reference fed identical data.
    let reference = DbServer::start(ServerConfig {
        engine: Engine::Redis,
        with_models: false,
        conn_read_timeout: Duration::from_millis(50),
        ..Default::default()
    })
    .unwrap();

    let mut c = Client::connect(addr).unwrap();
    let mut rc = Client::connect(reference.addr).unwrap();
    for step in 0..steps {
        for r in 0..ranks {
            let snap = t_const((step * ranks as u64 + r as u64) as f32, elems);
            c.put_tensor(&tensor_key("field", r, step), &snap).unwrap();
            rc.put_tensor(&tensor_key("field", r, step), &snap).unwrap();
        }
    }

    // Resident bytes under the cap, exactly the window retained.
    let store = driver.servers[0].store();
    assert!(store.n_bytes() <= cap);
    assert_eq!(store.n_bytes(), window * ranks as u64 * payload, "window resident");

    // Everything evicted was spilled — counters agree exactly.
    let info = c.info().unwrap();
    assert_eq!(info.spilled_keys, info.evicted_keys);
    assert_eq!(info.spilled_keys, (steps - window) * ranks as u64);
    assert_eq!(info.spilled_bytes, info.evicted_bytes);

    // Full-history equivalence: every generation ever published reads back
    // byte-exact — retired ones from the cold tier, resident ones hot —
    // and matches the unbounded append-mode reference.
    for step in 0..steps {
        for r in 0..ranks {
            let key = tensor_key("field", r, step);
            let want = rc.get_tensor(&key).unwrap();
            let got = if step < steps - window {
                c.cold_get(&key).unwrap()
            } else {
                c.get_tensor(&key).unwrap()
            };
            assert_eq!(got, want, "history diverged at {key}");
        }
    }

    // Trainer-side equivalence on the retained window (as in the spill-off
    // test), and the windowed loader needs no cold fallback for it.
    let latest = steps - 1;
    let mut dl = DataLoader::new(c, (0..ranks).collect(), "field", 11);
    dl.wait_for_step(latest, &PollConfig::default()).unwrap();
    let windowed = dl.gather_window(latest, window).unwrap();
    let mut rdl = DataLoader::new(rc, (0..ranks).collect(), "field", 11);
    let append = rdl.gather_window(latest, window).unwrap();
    assert_eq!(windowed, append, "retained window identical to append-mode");
    assert_eq!(dl.gens_cold(), 0, "retained window served hot");

    // And a *deep* windowed gather spanning retired generations completes
    // from the cold tier instead of skipping them.
    let deep = dl.gather_window(latest, steps).unwrap();
    assert_eq!(deep.len(), steps as usize * ranks, "full history via cold fallback");
    assert_eq!(dl.gens_skipped(), 0);
    assert!(dl.gens_cold() >= steps - window, "cold tier served the deep window");

    driver.shutdown();
    let _ = std::fs::remove_dir_all(&spill_base);
}

#[test]
fn overwrite_mode_is_flat_by_construction() {
    // The paper's overwrite mode: stable keys, no retention policy needed.
    let server = DbServer::start(ServerConfig {
        engine: Engine::KeyDb,
        with_models: false,
        ..Default::default()
    })
    .unwrap();
    let ranks = 4usize;
    let elems = 128usize;
    let mut c = Client::connect(server.addr).unwrap();
    for step in 0..stress_steps(100) {
        for r in 0..ranks {
            c.put_tensor(&stable_key("field", r), &t_const(step as f32, elems)).unwrap();
        }
        assert_eq!(
            server.store().n_bytes(),
            (ranks * elems * 4) as u64,
            "one generation resident at step {step}"
        );
    }
    // The consumer-side stable-key path sees the newest generation.
    let mut dl = DataLoader::new(c, (0..ranks).collect(), "field", 5);
    dl.wait_latest(&PollConfig::default()).unwrap();
    let got = dl.gather_latest().unwrap();
    assert_eq!(got.len(), ranks);
}

#[test]
fn sustained_backpressure_survives_via_snapshot_skipping() {
    // The backpressure acceptance path, deterministic and sequential: a
    // stalled field ("hog") pins the whole byte budget inside its protected
    // window, so every publish of the live field is rejected with Busy.
    // Under the old behavior that aborted the producer; with the governor
    // the loop keeps running — dropping snapshots and widening its stride —
    // and recovers to full rate once the stall clears.
    use situ::client::{GovernorConfig, PublishGovernor, RetryPolicy};

    let elems = 64usize;
    let payload = (elems * 4) as u64;
    let server = DbServer::start(ServerConfig {
        engine: Engine::KeyDb,
        with_models: false,
        retention: RetentionConfig::windowed(2, 2 * payload),
        conn_read_timeout: Duration::from_millis(50),
        ..Default::default()
    })
    .unwrap();
    let mut c = Client::connect(server.addr).unwrap();
    // The hog's two-generation window fills the cap exactly.
    c.put_tensor(&tensor_key("hog", 0, 0), &t_const(0.0, elems)).unwrap();
    c.put_tensor(&tensor_key("hog", 0, 1), &t_const(1.0, elems)).unwrap();

    let mut gov = PublishGovernor::new(GovernorConfig {
        retry: RetryPolicy::Backoff {
            initial: Duration::from_millis(1),
            cap: Duration::from_millis(4),
            retries: 2,
        },
        max_stride: 4,
    });
    let mut published = 0u64;
    let opportunities = 24u64;
    for opp in 0..opportunities {
        if opp == opportunities / 2 {
            // The stall clears mid-run (consumer drains the hog's window).
            c.del_keys(&[tensor_key("hog", 0, 0), tensor_key("hog", 0, 1)]).unwrap();
        }
        if !gov.should_publish() {
            continue;
        }
        let placed = gov
            .publish(|| c.put_tensor(&tensor_key("live", 0, published), &t_const(9.0, elems)))
            .expect("governed publish never surfaces Busy as fatal");
        if placed.is_some() {
            published += 1;
        }
    }
    let stats = gov.stats();
    assert!(stats.dropped > 0, "pressure phase dropped snapshots: {stats:?}");
    assert!(stats.skipped > 0, "stride skipping engaged: {stats:?}");
    assert!(stats.busy_retries > 0, "retries were attempted: {stats:?}");
    assert!(published >= 2, "run recovered after the stall: {stats:?}");
    assert_eq!(stats.published, published);
    assert_eq!(gov.stride(), 1, "stride decayed back to full rate");
    assert!(server.store().n_bytes() <= 2 * payload, "cap held throughout");
    let info = c.info().unwrap();
    assert!(info.busy_rejections > 0, "store counted the rejections");
    // The live field's newest window is resident (its own retention).
    assert!(c.exists(&tensor_key("live", 0, published - 1)).unwrap());
}
