//! Offline stub of the `xla` PJRT bindings.
//!
//! The build environment for this repository has no PJRT shared library, so
//! this crate provides the exact API surface `situ::runtime` consumes:
//! enough for the whole crate (database, protocol, client, store, benches)
//! to build and run.  `Literal` is fully functional — it is a plain
//! host-memory container — while `PjRtClient::compile` returns a clear
//! runtime error, so every in-database model execution path degrades to an
//! explicit `Error::Xla` instead of a link failure.  Swap this path
//! dependency for the real `xla` bindings to enable execution.

use std::borrow::Borrow;
use std::fmt;
use std::path::Path;

/// Error type mirroring the real bindings' stringly-typed errors.
#[derive(Debug, Clone)]
pub struct XlaError(pub String);

impl fmt::Display for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for XlaError {}

/// The name the real bindings export (downstream code writes `xla::Error`).
pub use self::XlaError as Error;

type Result<T> = std::result::Result<T, XlaError>;

/// Element types the AOT artifacts exchange (subset of XLA's set, plus a
/// few extras so downstream `match` arms keep a live catch-all).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[non_exhaustive]
pub enum ElementType {
    Pred,
    S8,
    S32,
    S64,
    U8,
    U32,
    F16,
    F32,
    F64,
}

impl ElementType {
    fn size(self) -> usize {
        match self {
            ElementType::Pred | ElementType::S8 | ElementType::U8 => 1,
            ElementType::F16 => 2,
            ElementType::S32 | ElementType::U32 | ElementType::F32 => 4,
            ElementType::S64 | ElementType::F64 => 8,
        }
    }
}

/// Native host types `Literal::to_vec` can decode into.
pub trait NativeType: Copy {
    const TY: ElementType;
    fn from_le(bytes: &[u8]) -> Self;
}

impl NativeType for f32 {
    const TY: ElementType = ElementType::F32;
    fn from_le(b: &[u8]) -> Self {
        f32::from_le_bytes([b[0], b[1], b[2], b[3]])
    }
}

impl NativeType for f64 {
    const TY: ElementType = ElementType::F64;
    fn from_le(b: &[u8]) -> Self {
        f64::from_le_bytes([b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7]])
    }
}

impl NativeType for i32 {
    const TY: ElementType = ElementType::S32;
    fn from_le(b: &[u8]) -> Self {
        i32::from_le_bytes([b[0], b[1], b[2], b[3]])
    }
}

impl NativeType for u8 {
    const TY: ElementType = ElementType::U8;
    fn from_le(b: &[u8]) -> Self {
        b[0]
    }
}

/// Array shape (element type + dims) of a [`Literal`].
#[derive(Debug, Clone)]
pub struct ArrayShape {
    ty: ElementType,
    dims: Vec<i64>,
}

impl ArrayShape {
    pub fn ty(&self) -> ElementType {
        self.ty
    }

    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

/// A host-memory tensor literal: little-endian row-major bytes plus shape.
/// Fully functional in the stub.
#[derive(Debug, Clone)]
pub struct Literal {
    shape: ArrayShape,
    data: Vec<u8>,
}

impl Literal {
    pub fn create_from_shape_and_untyped_data(
        ty: ElementType,
        dims: &[usize],
        untyped_data: &[u8],
    ) -> Result<Literal> {
        let n: usize = dims.iter().product();
        if n * ty.size() != untyped_data.len() {
            return Err(XlaError(format!(
                "literal payload {} bytes does not match {:?} x {:?}",
                untyped_data.len(),
                dims,
                ty
            )));
        }
        Ok(Literal {
            shape: ArrayShape { ty, dims: dims.iter().map(|d| *d as i64).collect() },
            data: untyped_data.to_vec(),
        })
    }

    pub fn array_shape(&self) -> Result<ArrayShape> {
        Ok(self.shape.clone())
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        if self.shape.ty != T::TY {
            return Err(XlaError(format!(
                "literal is {:?}, asked for {:?}",
                self.shape.ty,
                T::TY
            )));
        }
        Ok(self
            .data
            .chunks_exact(self.shape.ty.size())
            .map(T::from_le)
            .collect())
    }

    /// Destructure a tuple literal.  Stub literals are always arrays, and
    /// nothing can execute to produce a tuple, so this is unreachable in
    /// practice; it errors rather than panics to keep the contract total.
    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        Err(XlaError("stub literal is not a tuple".into()))
    }

    pub fn raw_data(&self) -> &[u8] {
        &self.data
    }
}

/// Parsed HLO module (the stub stores the text verbatim).
#[derive(Debug, Clone)]
pub struct HloModuleProto {
    text: String,
}

impl HloModuleProto {
    pub fn from_text_file(path: &Path) -> Result<HloModuleProto> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| XlaError(format!("read {}: {e}", path.display())))?;
        Ok(HloModuleProto { text })
    }

    pub fn text(&self) -> &str {
        &self.text
    }
}

/// A computation handle built from an [`HloModuleProto`].
#[derive(Debug, Clone)]
pub struct XlaComputation {
    proto: HloModuleProto,
}

impl XlaComputation {
    pub fn from_proto(proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { proto: proto.clone() }
    }

    pub fn proto(&self) -> &HloModuleProto {
        &self.proto
    }
}

/// PJRT client handle.  Construction succeeds (so the executor thread and
/// every data-plane component come up); compilation reports the stub.
pub struct PjRtClient {
    _priv: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient { _priv: () })
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(XlaError(
            "xla stub: PJRT is unavailable in this build; model execution is disabled \
             (replace rust/vendor/xla with the real bindings to enable it)"
                .into(),
        ))
    }
}

/// Compiled executable handle.  Unconstructible through the stub client;
/// the type exists so downstream signatures compile unchanged.
pub struct PjRtLoadedExecutable {
    _priv: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<L: Borrow<Literal>>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(XlaError("xla stub: execution unavailable".into()))
    }
}

/// Device buffer handle returned by an execution.
pub struct PjRtBuffer {
    _priv: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(XlaError("xla stub: no device buffers exist".into()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_f32() {
        let data: Vec<u8> = [1.0f32, -2.5, 3.25]
            .iter()
            .flat_map(|v| v.to_le_bytes())
            .collect();
        let lit = Literal::create_from_shape_and_untyped_data(ElementType::F32, &[3], &data)
            .unwrap();
        assert_eq!(lit.to_vec::<f32>().unwrap(), vec![1.0, -2.5, 3.25]);
        let shape = lit.array_shape().unwrap();
        assert_eq!(shape.ty(), ElementType::F32);
        assert_eq!(shape.dims(), &[3]);
        assert!(lit.to_vec::<i32>().is_err(), "dtype mismatch rejected");
    }

    #[test]
    fn literal_rejects_size_mismatch() {
        assert!(
            Literal::create_from_shape_and_untyped_data(ElementType::F32, &[2], &[0u8; 7])
                .is_err()
        );
    }

    #[test]
    fn client_comes_up_but_compile_reports_stub() {
        let client = PjRtClient::cpu().unwrap();
        let proto = HloModuleProto { text: "HloModule m".into() };
        let comp = XlaComputation::from_proto(&proto);
        let err = client.compile(&comp).unwrap_err();
        assert!(err.to_string().contains("stub"));
    }
}
